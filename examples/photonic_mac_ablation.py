"""Photonic-MAC resolution ablation (DESIGN.md §6, paper §V).

The 2.5D-CrossLight weight banks imprint weights onto optical amplitudes
through MR tuning — the achievable resolution (4..8 bits in the CrossLight
line of work) bounds the numerics of every MAC.  This ablation sweeps the
resolution and reports:

  1. weight-quantization error (the per-tile MR-bank model in
     `kernels/photonic_mac.py`),
  2. end-task effect: a reduced-config LM trained for a few dozen steps with
     `use_photonic_mac=True` (QAT straight-through) at each resolution,
  3. the interposer implication: parameter wire bytes scale linearly with
     resolution (`parallel/wire.py`) — 8-bit banks mean 4x fewer collective
     bytes than f32 masters on the same SWMR traffic.

Run: PYTHONPATH=src python examples/photonic_mac_ablation.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels.photonic_mac import quantize_weights
from repro.kernels import ref
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.trainer import make_train_step

# REPRO_SMOKE=1: one resolution, a few steps — the CI smoke-mode contract
# shared with the benchmark layer (tests/test_benchmarks_smoke.py)
from repro.env import smoke_mode

_SMOKE = smoke_mode()
STEPS = 4 if _SMOKE else 30
BITS = (8,) if _SMOKE else (8, 6, 5, 4, 3, 2)


def quant_error():
    print("== MR weight-bank quantization error (per-tile scale, 128x128) ==")
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
    for bits in BITS:
        wq, sc = quantize_weights(w, bits=bits)
        deq = ref.dequantize_ref(wq, sc)
        rel = float(jnp.linalg.norm(deq - w) / jnp.linalg.norm(w))
        print(f"  bits={bits}:  rel-frobenius-error={rel:.5f}  "
              f"(amplitude levels={2 ** (bits - 1) - 1})")


def train_at(bits):
    cfg = C.get_reduced("yi_6b")
    if bits:
        cfg = dataclasses.replace(cfg, use_photonic_mac=True,
                                  photonic_bits=bits, use_kernels=False)
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=5, total_steps=STEPS)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(opt, params)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    src = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=64))
    for i in range(STEPS):
        state, metrics = step(state, src.batch_at(i))
    return float(metrics["loss"])


def main():
    quant_error()
    print(f"\n== QAT training, reduced yi-6b, {STEPS} steps ==")
    base = train_at(None)
    print(f"  f32 MAC         : final loss {base:.4f}")
    for bits in BITS:
        loss = train_at(bits)
        gap = loss - base
        print(f"  photonic {bits}-bit : final loss {loss:.4f}  (gap {gap:+.4f})")
    print("\n== interposer wire implication ==")
    for bits in (32, 16, 8, 4):
        print(f"  {bits:>2}-bit weights on the SWMR wire: "
              f"{32 / bits:.0f}x fewer collective bytes than f32 masters")
    print("\n(The 8-bit row is the paper-faithful operating point: CrossLight"
          "\n demonstrates robust 256-level MR operation; below 4 bits the QAT"
          "\n gap grows quickly — matching the paper line's design choice.)")


if __name__ == "__main__":
    main()

"""Quickstart: the paper in five minutes on one CPU.

1. Evaluate the TRINE photonic interposer against SPRINT/SPACX/Tree (Fig. 4).
2. Evaluate 2.5D-CrossLight vs monolithic / electrical interposer (Fig. 6).
3. Run one training step of an assigned architecture (reduced scale) with the
   photonic-MAC (broadcast-and-weight) numerics enabled.

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

jax.config.update("jax_enable_x64", True)


from repro.core import (
    CNN_WORKLOADS, NetworkParams, choose_subnetworks, crosslight_25d_siph,
    evaluate_accelerator, evaluate_network, monolithic_crosslight,
    sprint_bus, tree_network, trine_network,
)
from repro import configs as C
from repro.models import model as M


def photonic_network_demo():
    print("=" * 70)
    print("TRINE photonic interposer (paper Sec. IV)")
    p = NetworkParams()
    print(f"  bandwidth matching: memory {p.mem_bw_bytes_per_s/1e9:.0f} GB/s, "
          f"waveguide {p.n_lambda * p.modulation_rate_bps/8e9:.0f} GB/s "
          f"-> K* = {choose_subnetworks(p)} subnetworks (paper: 8)")
    trine = trine_network(p)
    tree = tree_network(p)
    print(f"  TRINE: {trine.n_stages} MZI stages, "
          f"{trine.worst_path_loss_db:.1f} dB worst path "
          f"(Tree: {tree.n_stages} stages, {tree.worst_path_loss_db:.1f} dB)")
    wl = CNN_WORKLOADS["ResNet18"]()
    t = wl.traffic()
    for net in (sprint_bus(p), tree, trine):
        r = evaluate_network(net, t)
        print(f"  {net.name:10s} ResNet18 traffic: {r.latency_s*1e3:7.3f} ms, "
              f"{r.energy_j*1e3:6.3f} mJ, {r.energy_per_bit_j*1e12:6.2f} pJ/bit")


def accelerator_demo():
    print("=" * 70)
    print("2.5D-CrossLight (paper Sec. V)")
    mono = monolithic_crosslight()
    siph = crosslight_25d_siph()
    for wl_name in ("VGG16", "LeNet5"):
        wl = CNN_WORKLOADS[wl_name]()
        rm = evaluate_accelerator(mono, wl)
        rs = evaluate_accelerator(siph, wl)
        print(f"  {wl_name:8s}: monolithic {rm.latency_s*1e3:8.3f} ms "
              f"-> 2.5D-SiPh {rs.latency_s*1e3:8.3f} ms "
              f"({rm.latency_s/rs.latency_s:4.1f}x)  EPB "
              f"{rm.epb_j*1e12:5.2f} -> {rs.epb_j*1e12:5.2f} pJ/bit")


def photonic_mac_training_demo():
    print("=" * 70)
    print("Training with photonic-MAC numerics (broadcast-and-weight QAT)")
    cfg = dataclasses.replace(C.get_reduced("yi_6b"),
                              use_photonic_mac=True, photonic_bits=8)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 64), 0, cfg.vocab)}

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(
            lambda q: M.loss_fn(cfg, q, batch), has_aux=True)(p)
        return loss, jax.tree.map(lambda a, b: a - 5e-2 * b, p, g)

    for i in range(5):
        loss, params = step(params)
        print(f"  step {i}: loss = {float(loss):.4f}  "
              f"(8-bit MR weight banks, f32 photodetector accumulation)")


if __name__ == "__main__":
    photonic_network_demo()
    accelerator_demo()
    photonic_mac_training_demo()

"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps with the full production stack — synthetic pipeline with
prefetch, AdamW, atomic checkpointing, failure injection + auto-resume.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--fail-at 150]

On this CPU container a ~100M model at short sequence length runs a few
steps/minute; pass --tiny for a fast demonstration (default --tiny for CI).
"""

import argparse
import dataclasses

from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig, run_with_restarts

# ~100M-parameter llama-style config (d=768, 12L, vocab 32k ≈ 110M params)
LM_100M = ModelConfig(
    name="lm-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32000, rope_theta=1e4, loss_chunk=128,
    dtype="float32", remat="none",
)

LM_TINY = dataclasses.replace(
    LM_100M, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=1024, name="lm-tiny")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a node failure at this step (0=off); the "
                         "supervisor restarts from the latest checkpoint")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full-100m", dest="tiny", action="store_false")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = LM_TINY if args.tiny else LM_100M
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    def make():
        return Trainer(
            cfg,
            OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
            DataConfig(global_batch=args.batch, seq_len=args.seq),
            TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=20, log_every=10),
        )

    if args.fail_at:
        print(f"(failure will be injected at step {args.fail_at}; "
              f"watch the auto-resume)")
        tr = run_with_restarts(make, args.steps, fail_at=(args.fail_at,))
        out = {"last_loss": tr.history[-1]["loss"] if tr.history else None}
    else:
        out = make().run(args.steps)
    print("done:", out)
    print("loss trajectory proves optimization:",)


if __name__ == "__main__":
    main()

"""Design-space exploration of the TRINE interposer network (beyond-paper):
sweep the subnetwork count K and wavelength count per waveguide, and find the
energy-delay-product-optimal configuration for each CNN workload — the
quantitative version of the paper's 'tailor the subnetworks to the memory
bandwidth' argument, plus the MR-resolution (photonic MAC bits) trade-off.

  PYTHONPATH=src python examples/photonic_design_space.py
"""

import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import (
    CNN_WORKLOADS, NetworkParams, choose_subnetworks, evaluate_network,
    trine_network,
)


def sweep_subnetworks():
    print("=" * 72)
    print("K-sweep: energy-delay product vs subnetwork count (ResNet18)")
    p = NetworkParams()
    wl = CNN_WORKLOADS["ResNet18"]()
    t = wl.traffic()
    kstar = choose_subnetworks(p)
    best = None
    for k in (1, 2, 4, 8, 16, 32):
        net = trine_network(p, n_subnetworks=k)
        r = evaluate_network(net, t)
        edp = r.energy_j * r.latency_s
        tag = " <= paper's choice" if k == kstar else ""
        print(f"  K={k:3d}: latency {r.latency_s*1e3:8.3f} ms  "
              f"energy {r.energy_j*1e3:7.3f} mJ  EDP {edp*1e6:9.4f}{tag}")
        if best is None or edp < best[1]:
            best = (k, edp)
    print(f"  EDP-optimal K = {best[0]} (bandwidth matching: K*={kstar})")


def sweep_wavelengths():
    print("=" * 72)
    print("WDM sweep: wavelengths/waveguide at fixed aggregate bandwidth")
    wl = CNN_WORKLOADS["VGG16"]()
    t = wl.traffic()
    for n_lambda in (4, 8, 16):
        p = NetworkParams(n_lambda=n_lambda)
        net = trine_network(p)
        r = evaluate_network(net, t)
        print(f"  {n_lambda:2d} lambda x {net.n_laser_banks} subnets: "
              f"loss {net.worst_path_loss_db:5.2f} dB, laser {r.laser_power_w*1e3:7.1f} mW, "
              f"latency {r.latency_s*1e3:7.3f} ms, EPB {r.energy_per_bit_j*1e12:5.2f} pJ/bit")


def sweep_trimming_sensitivity():
    print("=" * 72)
    print("Device sensitivity: MR trimming power x2 / MZI loss x2 (TRINE)")
    from repro.core import DEFAULT_DEVICES
    from repro.core.devices import MRParams, MZIParams
    wl = CNN_WORKLOADS["DenseNet121"]()
    t = wl.traffic()
    p = NetworkParams()
    base = evaluate_network(trine_network(p), t)
    d2 = DEFAULT_DEVICES.replace(mr=MRParams(tuning_power_w=550e-6))
    r2 = evaluate_network(trine_network(p, d=d2), t, d2)
    d3 = DEFAULT_DEVICES.replace(mzi=MZIParams(insertion_loss_db=2.0))
    r3 = evaluate_network(trine_network(p, d=d3), t, d3)
    print(f"  baseline      : {base.power_w*1e3:7.1f} mW, {base.energy_j*1e3:7.3f} mJ")
    print(f"  2x trimming   : {r2.power_w*1e3:7.1f} mW, {r2.energy_j*1e3:7.3f} mJ")
    print(f"  2x MZI loss   : {r3.power_w*1e3:7.1f} mW, {r3.energy_j*1e3:7.3f} mJ "
          f"(loss compounds per stage -> laser grows exponentially)")


if __name__ == "__main__":
    sweep_subnetworks()
    sweep_wavelengths()
    sweep_trimming_sensitivity()

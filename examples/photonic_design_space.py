"""Design-space exploration of the TRINE interposer network (beyond-paper):
sweep the subnetwork count K and wavelength count per waveguide, and find the
energy-delay-product-optimal configuration for each CNN workload — the
quantitative version of the paper's 'tailor the subnetworks to the memory
bandwidth' argument, plus the MR-resolution (photonic MAC bits) trade-off.

All sections run on the batched sweep engine (repro.core.sweep): the grids
below are struct-of-arrays columns evaluated by jitted kernels, not
per-config Python loops.  The closing sections use the search engine
(repro.core.search): a streaming per-workload Pareto front over the full
(topology x gateways x lambda x memory x rate x geometry) space — evaluated
in fixed-size chunks so memory stays bounded no matter the grid size — a
joint network x chiplet-mix co-design front, jax.grad refinement of the
best frontier point through the continuous columns, and joint accelerator +
network refinement of the co-design frontier (`refine_codesign`: relaxed
descent over per-chiplet n_units/vector_size, mac_rate_hz and
lambda_slot_energy_j alongside the network axes, snapped back to feasible
integer designs and round-tripped into a `core.fabric.Fabric`), and a
six-CNN joint trust-region refinement (`refine_trust_region`: second-order
descent + coordinate-wise integer line search against the weighted-geomean
EDP of all six paper CNNs at once).

  PYTHONPATH=src python examples/photonic_design_space.py
  REPRO_SMOKE=1 PYTHONPATH=src python examples/photonic_design_space.py  # tiny grids
"""


import jax

jax.config.update("jax_enable_x64", True)  # float64 sweep kernel, like run.py

import numpy as np

from repro.core import CNN_WORKLOADS, ChipletSpec, NetworkParams, choose_subnetworks
from repro.core.search import (
    codesign_config_at,
    codesign_pareto,
    pareto_search,
    refine_front_point,
)
from repro.core.sweep import grid_spec, sweep
from repro.env import smoke_mode

SMOKE = smoke_mode()


def sweep_subnetworks():
    print("=" * 72)
    print("K-sweep: energy-delay product vs subnetwork count (ResNet18)")
    t = CNN_WORKLOADS["ResNet18"]().traffic()
    kstar = choose_subnetworks(NetworkParams())
    ks = (1, 2, 4, 8, 16, 32)
    res = sweep(t, topologies=("trine",), n_subnetworks=ks)
    edp = res.metrics["energy_j"] * res.metrics["latency_s"]
    for i, k in enumerate(ks):
        tag = " <= paper's choice" if k == kstar else ""
        print(f"  K={k:3d}: latency {res.metrics['latency_s'][i] * 1e3:8.3f} ms  "
              f"energy {res.metrics['energy_j'][i] * 1e3:7.3f} mJ  "
              f"EDP {edp[i] * 1e6:9.4f}{tag}")
    print(f"  EDP-optimal K = {ks[int(np.argmin(edp))]} (bandwidth matching: K*={kstar})")


def sweep_wavelengths():
    print("=" * 72)
    print("WDM sweep: wavelengths/waveguide at fixed aggregate bandwidth")
    t = CNN_WORKLOADS["VGG16"]().traffic()
    lams = (4, 8, 16)
    res = sweep(t, topologies=("trine",), n_lambda=lams)
    for i, n_lambda in enumerate(lams):
        print(f"  {n_lambda:2d} lambda x {int(res.nets['n_laser_banks'][i])} subnets: "
              f"loss {res.nets['worst_path_loss_db'][i]:5.2f} dB, "
              f"laser {res.metrics['laser_power_w'][i] * 1e3:7.1f} mW, "
              f"latency {res.metrics['latency_s'][i] * 1e3:7.3f} ms, "
              f"EPB {res.metrics['energy_per_bit_j'][i] * 1e12:5.2f} pJ/bit")


def sweep_trimming_sensitivity():
    print("=" * 72)
    print("Device sensitivity: MR trimming power x2 / MZI loss x2 (TRINE)")
    t = CNN_WORKLOADS["DenseNet121"]().traffic()
    # device leaves are grid axes too: a 2x2 corner sweep in one call
    res = sweep(t, topologies=("trine",),
                **{"mr.tuning_power_w": (275e-6, 550e-6),
                   "mzi.insertion_loss_db": (1.0, 2.0)})
    p = res.metric("power_w")[0] * 1e3      # (tuning, mzi_loss)
    e = res.metric("energy_j")[0] * 1e3
    print(f"  baseline      : {p[0, 0]:7.1f} mW, {e[0, 0]:7.3f} mJ")
    print(f"  2x trimming   : {p[1, 0]:7.1f} mW, {e[1, 0]:7.3f} mJ")
    print(f"  2x MZI loss   : {p[0, 1]:7.1f} mW, {e[0, 1]:7.3f} mJ "
          f"(loss compounds per stage -> laser grows exponentially)")


def sweep_full_design_space():
    print("=" * 72)
    topos = ("sprint", "spacx", "tree", "trine")
    if SMOKE:
        axes = dict(n_gateways=(16, 32), n_lambda=(4, 8))
    else:
        axes = dict(
            n_gateways=(8, 16, 24, 32, 48, 64),
            n_lambda=(2, 4, 8, 16),
            mem_bw_bytes_per_s=(25e9, 50e9, 100e9, 200e9),
            modulation_rate_bps=(8e9, 10e9, 12e9),
            interposer_side_cm=(2.0, 3.0, 4.0),
        )
    n_grid = len(topos) * int(np.prod([len(v) for v in axes.values()]))
    print(f"Full design-space search: {n_grid} configs/workload, batched")
    for name in ("ResNet18", "VGG16") if not SMOKE else ("ResNet18",):
        t = CNN_WORKLOADS[name]().traffic()
        res = sweep(t, topologies=topos, **axes)
        edp = res.metrics["energy_j"] * res.metrics["latency_s"]
        i = int(np.argmin(edp))
        cfg = res.config_at(i)
        axes_str = ", ".join(
            f"{k}={v:g}" for k, v in cfg.items() if k != "topology")
        print(f"  {name:10s}: EDP-optimal {res.model_at(i).name:9s} "
              f"({axes_str})")
        print(f"  {'':10s}  latency {res.metrics['latency_s'][i] * 1e3:.3f} ms, "
              f"energy {res.metrics['energy_j'][i] * 1e3:.3f} mJ, "
              f"laser {res.metrics['laser_power_w'][i] * 1e3:.1f} mW")


def pareto_and_refine():
    """Streaming Pareto frontier + gradient refinement (core.search)."""
    print("=" * 72)
    topos = ("sprint", "spacx", "tree", "trine")
    if SMOKE:
        axes = dict(n_gateways=(16, 32, 64), n_lambda=(4, 8))
        chunk = 8
    else:
        axes = dict(
            n_gateways=(8, 16, 24, 32, 40, 48, 56, 64),
            n_lambda=(2, 4, 8, 16),
            mem_bw_bytes_per_s=(25e9, 50e9, 100e9, 200e9),
            modulation_rate_bps=(8e9, 10e9, 12e9),
            interposer_side_cm=(2.0, 3.0, 4.0),
        )
        chunk = 4096
    spec = grid_spec(topos, **axes)
    names = ("ResNet18",) if SMOKE else ("ResNet18", "VGG16")
    traffics = [CNN_WORKLOADS[n]().traffic() for n in names]
    fronts = pareto_search(traffics, topologies=topos, chunk_size=chunk,
                           **axes)
    print(f"Streaming Pareto search: {spec.n} configs/workload in "
          f"{chunk}-config chunks (bounded memory)")
    for name, front in zip(names, fronts):
        edp = front.points[:, 0] * front.points[:, 1]  # latency * energy
        i = int(np.argmin(edp))
        cfg = front.configs(spec)[i]
        axes_str = ", ".join(f"{k}={v:g}" for k, v in cfg.items()
                             if k != "topology")
        print(f"  {name:10s}: {front.size:3d} frontier points; best-EDP "
              f"{cfg['topology']} ({axes_str})")
        print(f"  {'':10s}  latency {front.points[i, 0] * 1e3:.3f} ms, "
              f"energy {front.points[i, 1] * 1e3:.3f} mJ, "
              f"power {front.points[i, 2]:.2f} W")

    # descend from the ResNet18 best-EDP point through the continuous axes
    front = fronts[0]
    edp = front.points[:, 0] * front.points[:, 1]
    best = int(front.indices[int(np.argmin(edp))])
    r = refine_front_point(spec, traffics[0], best,
                           steps=8 if SMOKE else 48, lr=0.1)
    moved = {k: f"{r['start'][k]:.3g}->{v:.3g}"
             for k, v in r["refined"].items()
             if abs(v - r["start"][k]) / r["start"][k] > 1e-3}
    print(f"Gradient refinement (jax.grad through the {r['topology']} "
          f"kernel): EDP {r['start_value']:.3e} -> {r['refined_value']:.3e} "
          f"({100 * r['improvement']:.1f}% better)")
    print(f"  moved axes: {moved or 'none (already locally optimal)'}")


def codesign_search():
    """Joint network x chiplet-mix frontier (paper Sec. V co-design)."""
    print("=" * 72)
    wl = CNN_WORKLOADS["ResNet18"]()
    C = ChipletSpec
    mixes = [
        [C(512, 32)],                                      # homogeneous
        [C(512, 9), C(512, 27), C(512, 49), C(512, 128)],  # paper Fig. 5
        [C(256, 16), C(256, 64), C(256, 256)],
    ]
    if SMOKE:
        axes = dict(n_gateways=(16, 64), n_lambda=(4, 8))
    else:
        axes = dict(n_gateways=(16, 32, 48, 64), n_lambda=(2, 4, 8, 16),
                    mem_bw_bytes_per_s=(50e9, 100e9, 200e9),
                    modulation_rate_bps=(8e9, 12e9))
    front, spec = codesign_pareto(wl, mixes, topologies=("trine", "elec"),
                                  chunk_size=16 if SMOKE else 4096, **axes)
    n_joint = spec.n * len(mixes)
    edp = front.points[:, 0] * front.points[:, 1]
    cfg = codesign_config_at(spec, mixes, int(front.indices[int(np.argmin(edp))]))
    vecs = "+".join(str(c.vector_size) for c in cfg["chiplets"])
    print(f"Co-design search (ResNet18): {n_joint} joint (network x "
          f"chiplet-mix) points -> {front.size} frontier points")
    print(f"  best-EDP: {cfg['topology']} interposer, chiplet vecs [{vecs}], "
          f"G={cfg['n_gateways']:g}, lambda={cfg['n_lambda']:g}")
    return front, spec, mixes


def codesign_refine(front, spec, mixes):
    """Joint accelerator + network gradient refinement of the co-design
    frontier (core.search.refine_codesign): relax the discrete accelerator
    axes, descend, snap back to feasible integer designs, and round-trip
    the refined winner into a `core.fabric.Fabric` link model."""
    print("=" * 72)
    from repro.core.fabric import Fabric
    from repro.core.search import refine_front

    wl = CNN_WORKLOADS["ResNet18"]()
    out = refine_front(front, spec, mixes, wl, top_k=3,
                       steps=8 if SMOKE else 32, lr=0.1)
    print(f"Co-design refinement: top-3 EDP seeds descended jointly over "
          f"accelerator + network axes, then round-and-rescored")
    for r in out["results"]:
        seed_v, ref_v = r["seed"]["value"], r["refined"]["value"]
        vecs = "+".join(str(c.vector_size) for c in r["refined"]["chiplets"]
                        if c.n_units > 0)
        print(f"  seed #{r['flat_index']}: EDP {seed_v:.3e} -> {ref_v:.3e} "
              f"({100 * r['improvement']:.1f}% better), "
              f"chiplet vecs [{vecs}]")
    print(f"  merged front: {out['seed_front'].size} -> "
          f"{out['front'].size} points "
          f"({out['n_improved']}/{len(out['results'])} seeds improved)")
    top = sorted(out["sensitivity"].items(), key=lambda kv: -kv[1])[:3]
    print("  most-binding axes (mean |grad| at seed): "
          + ", ".join(f"{k}={v:.3f}" for k, v in top))
    # the refined config dicts round-trip straight into the Fabric bridge
    # (compute-side keys are ignored; network axes override the preset)
    best = min(out["results"], key=lambda r: r["refined"]["value"])
    fb = Fabric.from_config(best["refined"]["config"], name="refined-best")
    print(f"  refined best as Fabric: cross-pod "
          f"{fb.cross_pod_bw_bytes_per_s / 1e9:.1f} GB/s, "
          f"link latency {fb.link_latency_s * 1e9:.0f} ns")


def codesign_refine_six_cnn(front, spec, mixes):
    """Trust-region multi-workload refinement: one design, all six CNNs.

    The second-order engine (`refine_trust_region`) refines the best-EDP
    frontier seed against the weighted-geomean EDP of ALL six paper CNNs at
    once — log-space trust-region descent on the relaxed objective, then a
    coordinate-wise integer line search over the discrete axes (per-chiplet
    n_units/vector_size and n_gateways) — so the refined interposer serves
    the whole workload portfolio instead of overfitting one network.  The
    final integer design round-trips into a `core.fabric.Fabric`."""
    print("=" * 72)
    from repro.core.fabric import Fabric
    from repro.core.search import refine_trust_region

    wls = [CNN_WORKLOADS[n]() for n in
           ("DenseNet121", "ResNet18", "LeNet5", "VGG16", "MobileNetV2",
            "EfficientNetB0")]
    edp = front.points[:, 0] * front.points[:, 1]
    seed = int(front.indices[int(np.argmin(edp))])
    r = refine_trust_region(
        spec, mixes, wls, seed, steps=4 if SMOKE else 24,
        refine_axes=("modulation_rate_bps", "mem_bw_bytes_per_s",
                     "interposer_side_cm", "n_gateways"))
    names = "+".join(w.name for w in wls)
    print(f"Six-CNN joint refinement ({names}):")
    print(f"  geomean EDP {r['seed']['value']:.3e} -> "
          f"{r['refined']['value']:.3e} "
          f"({100 * r['improvement']:.1f}% better), trust region "
          f"{r['tr_stats']['accepted']} accepted / "
          f"{r['tr_stats']['rejected']} rejected steps, line search scored "
          f"{r['line_search']['n_scored']} integer designs")
    for w, m in zip(wls, r["refined"]["per_workload"]):
        print(f"    {w.name:16s} latency {m['latency_s']:.3e} s, "
              f"energy {m['energy_j']:.3e} J")
    fb = Fabric.from_config(r["refined"]["config"], name="six-cnn-best")
    print(f"  six-CNN best as Fabric: cross-pod "
          f"{fb.cross_pod_bw_bytes_per_s / 1e9:.1f} GB/s, "
          f"link latency {fb.link_latency_s * 1e9:.0f} ns")


def fabric_whatif(front, spec, mixes):
    """Frontier -> Fabric link models -> Layer-B roofline what-if: price one
    LLM serving cell (yi_34b decode) under the metallic ICI baseline and
    each deduped frontier design (core.fabric closes the search->system
    loop; benchmarks.fabric_whatif is the full arch x shape version)."""
    print("=" * 72)
    from repro.core import fabrics_from_front, metallic_ici
    from repro.launch.hlo_analysis import HloStats, roofline

    fabs = [metallic_ici()] + fabrics_from_front(
        front, spec, mixes=mixes, max_fabrics=3)
    # a decode step on the (2,16,16) mesh: TP all-reduces dominate the wire
    stats = HloStats(dot_flops=1.7e10, dot_bytes=0.0, op_result_bytes=0.0,
                     collective_bytes=25.8e6, collective_op_bytes={},
                     collective_op_counts={"all-reduce": 121}, max_trip=1,
                     collective_bytes_raw=25.8e6)
    print(f"Fabric what-if (yi_34b decode cell): {len(fabs)} fabrics from "
          f"{front.size} frontier points")
    for fb in fabs:
        rf = roofline(stats, {}, stats.dot_flops, io_bytes=2.15e9, fabric=fb)
        step = max(rf.compute_s, rf.memory_s, rf.collective_s)
        print(f"  {fb.name:24s} cross-pod {fb.cross_pod_bw_bytes_per_s / 1e9:6.1f} GB/s: "
              f"step {step * 1e3:6.2f} ms, collective {rf.collective_s * 1e3:6.2f} ms "
              f"-> {rf.bottleneck}-bound")


if __name__ == "__main__":
    sweep_subnetworks()
    sweep_wavelengths()
    sweep_trimming_sensitivity()
    sweep_full_design_space()
    pareto_and_refine()
    front, spec, mixes = codesign_search()
    codesign_refine(front, spec, mixes)
    codesign_refine_six_cnn(front, spec, mixes)
    fabric_whatif(front, spec, mixes)

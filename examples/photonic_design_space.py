"""Design-space exploration of the TRINE interposer network (beyond-paper):
sweep the subnetwork count K and wavelength count per waveguide, and find the
energy-delay-product-optimal configuration for each CNN workload — the
quantitative version of the paper's 'tailor the subnetworks to the memory
bandwidth' argument, plus the MR-resolution (photonic MAC bits) trade-off.

All sections run on the batched sweep engine (repro.core.sweep): the grids
below — including the closing full design-space search over thousands of
configurations — are struct-of-arrays columns evaluated by one jitted call
each, not per-config Python loops.

  PYTHONPATH=src python examples/photonic_design_space.py
  REPRO_SMOKE=1 PYTHONPATH=src python examples/photonic_design_space.py  # tiny grids
"""

import os

import jax

jax.config.update("jax_enable_x64", True)  # float64 sweep kernel, like run.py

import numpy as np

from repro.core import CNN_WORKLOADS, NetworkParams, choose_subnetworks
from repro.core.sweep import sweep

SMOKE = os.environ.get("REPRO_SMOKE", "0").strip().lower() in (
    "1", "true", "yes", "on")


def sweep_subnetworks():
    print("=" * 72)
    print("K-sweep: energy-delay product vs subnetwork count (ResNet18)")
    t = CNN_WORKLOADS["ResNet18"]().traffic()
    kstar = choose_subnetworks(NetworkParams())
    ks = (1, 2, 4, 8, 16, 32)
    res = sweep(t, topologies=("trine",), n_subnetworks=ks)
    edp = res.metrics["energy_j"] * res.metrics["latency_s"]
    for i, k in enumerate(ks):
        tag = " <= paper's choice" if k == kstar else ""
        print(f"  K={k:3d}: latency {res.metrics['latency_s'][i] * 1e3:8.3f} ms  "
              f"energy {res.metrics['energy_j'][i] * 1e3:7.3f} mJ  "
              f"EDP {edp[i] * 1e6:9.4f}{tag}")
    print(f"  EDP-optimal K = {ks[int(np.argmin(edp))]} (bandwidth matching: K*={kstar})")


def sweep_wavelengths():
    print("=" * 72)
    print("WDM sweep: wavelengths/waveguide at fixed aggregate bandwidth")
    t = CNN_WORKLOADS["VGG16"]().traffic()
    lams = (4, 8, 16)
    res = sweep(t, topologies=("trine",), n_lambda=lams)
    for i, n_lambda in enumerate(lams):
        print(f"  {n_lambda:2d} lambda x {int(res.nets['n_laser_banks'][i])} subnets: "
              f"loss {res.nets['worst_path_loss_db'][i]:5.2f} dB, "
              f"laser {res.metrics['laser_power_w'][i] * 1e3:7.1f} mW, "
              f"latency {res.metrics['latency_s'][i] * 1e3:7.3f} ms, "
              f"EPB {res.metrics['energy_per_bit_j'][i] * 1e12:5.2f} pJ/bit")


def sweep_trimming_sensitivity():
    print("=" * 72)
    print("Device sensitivity: MR trimming power x2 / MZI loss x2 (TRINE)")
    t = CNN_WORKLOADS["DenseNet121"]().traffic()
    # device leaves are grid axes too: a 2x2 corner sweep in one call
    res = sweep(t, topologies=("trine",),
                **{"mr.tuning_power_w": (275e-6, 550e-6),
                   "mzi.insertion_loss_db": (1.0, 2.0)})
    p = res.metric("power_w")[0] * 1e3      # (tuning, mzi_loss)
    e = res.metric("energy_j")[0] * 1e3
    print(f"  baseline      : {p[0, 0]:7.1f} mW, {e[0, 0]:7.3f} mJ")
    print(f"  2x trimming   : {p[1, 0]:7.1f} mW, {e[1, 0]:7.3f} mJ")
    print(f"  2x MZI loss   : {p[0, 1]:7.1f} mW, {e[0, 1]:7.3f} mJ "
          f"(loss compounds per stage -> laser grows exponentially)")


def sweep_full_design_space():
    print("=" * 72)
    topos = ("sprint", "spacx", "tree", "trine")
    if SMOKE:
        axes = dict(n_gateways=(16, 32), n_lambda=(4, 8))
    else:
        axes = dict(
            n_gateways=(8, 16, 24, 32, 48, 64),
            n_lambda=(2, 4, 8, 16),
            mem_bw_bytes_per_s=(25e9, 50e9, 100e9, 200e9),
            modulation_rate_bps=(8e9, 10e9, 12e9),
            interposer_side_cm=(2.0, 3.0, 4.0),
        )
    n_grid = len(topos) * int(np.prod([len(v) for v in axes.values()]))
    print(f"Full design-space search: {n_grid} configs/workload, batched")
    for name in ("ResNet18", "VGG16") if not SMOKE else ("ResNet18",):
        t = CNN_WORKLOADS[name]().traffic()
        res = sweep(t, topologies=topos, **axes)
        edp = res.metrics["energy_j"] * res.metrics["latency_s"]
        i = int(np.argmin(edp))
        cfg = res.config_at(i)
        axes_str = ", ".join(
            f"{k}={v:g}" for k, v in cfg.items() if k != "topology")
        print(f"  {name:10s}: EDP-optimal {res.model_at(i).name:9s} "
              f"({axes_str})")
        print(f"  {'':10s}  latency {res.metrics['latency_s'][i] * 1e3:.3f} ms, "
              f"energy {res.metrics['energy_j'][i] * 1e3:.3f} mJ, "
              f"laser {res.metrics['laser_power_w'][i] * 1e3:.1f} mW")


if __name__ == "__main__":
    sweep_subnetworks()
    sweep_wavelengths()
    sweep_trimming_sensitivity()
    sweep_full_design_space()

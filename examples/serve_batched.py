"""Batched serving example: prefill + greedy decode with KV cache across a
request batch, with per-phase throughput — the serving-path counterpart of
the decode_32k / long_500k dry-run cells.

  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b

REPRO_SMOKE=1 shrinks the run (reduced model, batch 2, 16-token prompts,
4 new tokens) so the tier-1 smoke test can execute the full serve path —
prefill, decode loop, KV cache — in ~15s on the CPU container instead of
compile-checking only.
"""

import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.env import smoke_mode

SMOKE = smoke_mode()

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=2 if SMOKE else 4)
    ap.add_argument("--prompt-len", type=int, default=16 if SMOKE else 64)
    ap.add_argument("--max-new", type=int, default=4 if SMOKE else 16)
    args = ap.parse_args()
    # the serving driver is a first-class launcher; this example invokes it
    # the way an operator would
    sys.exit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len),
        "--max-new", str(args.max_new),
    ], env={"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin"}))

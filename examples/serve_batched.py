"""Batched serving example: prefill + greedy decode with KV cache across a
request batch, with per-phase throughput — the serving-path counterpart of
the decode_32k / long_500k dry-run cells.

  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
"""

import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    # the serving driver is a first-class launcher; this example invokes it
    # the way an operator would
    sys.exit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len),
        "--max-new", str(args.max_new),
    ], env={"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin"}))

"""Continuous-batching serving demo: ragged requests stream through a fixed
pool of cache slots (vLLM-style iteration-level scheduling) — the serving
counterpart of the paper's bandwidth-matching argument: keep the provisioned
lanes (batch slots) busy under ragged load.

  PYTHONPATH=src python examples/continuous_batching.py --arch yi-6b
"""

import argparse
import time

import jax
import numpy as np

from repro import configs as C
from repro.models import model as M
from repro.serve.engine import ContinuousBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    eng = ContinuousBatcher(cfg, params, n_slots=args.slots,
                            max_len=args.max_len)

    rng = np.random.default_rng(0)
    total_new = 0
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        max_new = int(rng.integers(4, 16))
        prompt = list(rng.integers(2, cfg.vocab, size=plen))
        eng.submit(prompt, max_new)
        total_new += max_new

    t0 = time.perf_counter()
    finished = eng.run()
    dt = time.perf_counter() - t0
    print(f"{len(finished)} requests, {total_new} new tokens through "
          f"{args.slots} slots in {dt:.2f}s ({total_new/dt:.0f} tok/s incl. "
          f"compiles)")
    for r in finished[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()

"""Paper Fig. 4: TRINE vs SPACX, SPRINT, Tree — interposer network power,
latency, and energy over six CNN workloads, normalized to SPRINT.

Evaluated through the batched sweep engine (core.sweep): one struct-of-arrays
grid of the four topologies, all six workload traffics broadcast against it,
every metric produced by a single jitted call.

Validates the paper's qualitative claims:
  * TRINE: best latency and energy of all four networks,
  * TRINE laser power > SPACX and > Tree (multiple subnetwork overhead),
  * TRINE trimming power > SPACX and > Tree (more MR banks),
  * Tree: latency-poor (one waveguide of memory bandwidth, 5 stages).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (
    CNN_WORKLOADS,
    NetworkParams,
    choose_subnetworks,
    tree_network,
    trine_network,
)
from repro.core.sweep import build_grid, evaluate_columns, network_columns

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

TOPOLOGIES = ("sprint", "spacx", "tree", "trine")


def _display_names(nets) -> list:
    ks = nets["n_laser_banks"]
    by_key = {"sprint": "SPRINT", "spacx": "SPACX", "tree": "Tree"}
    return [by_key.get(t, f"TRINE-{int(ks[j])}")
            for j, t in enumerate(TOPOLOGIES)]


def run(csv: bool = True) -> dict:
    p = NetworkParams()
    grid = build_grid(TOPOLOGIES)          # paper defaults, one row/topology
    nets = network_columns(grid)
    names = _display_names(nets)

    workloads = [factory() for factory in CNN_WORKLOADS.values()]
    traffics = [wl.traffic() for wl in workloads]
    bits = np.asarray([[t.total_bits] for t in traffics])        # (W, 1)
    xfers = np.asarray([[t.n_transfers] for t in traffics])

    evaluate_columns(nets, grid.cols, bits, xfers)  # warm the jit cache
    t0 = time.perf_counter()
    metrics = evaluate_columns(nets, grid.cols, bits, xfers)     # (W, topo)
    n_cells = metrics["power_w"].size
    us = (time.perf_counter() - t0) * 1e6 / max(1, n_cells)

    out = {
        "params": {
            "n_gateways": p.n_gateways,
            "mem_bw_GBps": p.mem_bw_bytes_per_s / 1e9,
            "n_subnetworks": choose_subnetworks(p),
            "trine_stages": trine_network(p).n_stages,
            "tree_stages": tree_network(p).n_stages,
        },
        "rows": [],
    }
    base_j = names.index("SPRINT")
    for wi, wl in enumerate(workloads):
        for j, name in enumerate(names):
            out["rows"].append(
                {
                    "cnn": wl.name,
                    "network": name,
                    "power_norm": metrics["power_w"][wi, j] / metrics["power_w"][wi, base_j],
                    "latency_norm": metrics["latency_s"][wi, j] / metrics["latency_s"][wi, base_j],
                    "energy_norm": metrics["energy_j"][wi, j] / metrics["energy_j"][wi, base_j],
                    "power_w": metrics["power_w"][wi, j],
                    "latency_s": metrics["latency_s"][wi, j],
                    "energy_j": metrics["energy_j"][wi, j],
                    "laser_w": metrics["laser_power_w"][wi, j],
                    "trimming_w": metrics["trimming_power_w"][wi, j],
                }
            )

    trine = [r for r in out["rows"] if r["network"].startswith("TRINE")]
    spacx = [r for r in out["rows"] if r["network"] == "SPACX"]
    tree = [r for r in out["rows"] if r["network"] == "Tree"]
    checks = {
        "trine_best_latency": all(
            t["latency_norm"] <= min(r["latency_norm"] for r in out["rows"]
                                     if r["cnn"] == t["cnn"] and r["network"] != t["network"])
            for t in trine if t["cnn"] != "LeNet5"
        ),
        # LeNet5 excluded: too small to amortize TRINE's static power -- the
        # same platform-underutilization exception the paper grants in Fig. 6
        "trine_best_energy": all(
            t["energy_norm"] <= min(r["energy_norm"] for r in out["rows"]
                                    if r["cnn"] == t["cnn"] and r["network"] != t["network"])
            for t in trine if t["cnn"] != "LeNet5"
        ),
        "trine_laser_gt_spacx_tree": all(
            t["laser_w"] > s["laser_w"] and t["laser_w"] > tr["laser_w"]
            for t, s, tr in zip(trine, spacx, tree)
        ),
        "trine_trimming_gt_spacx_tree": all(
            t["trimming_w"] > s["trimming_w"] and t["trimming_w"] > tr["trimming_w"]
            for t, s, tr in zip(trine, spacx, tree)
        ),
        "paper_stage_counts": out["params"]["trine_stages"] == 2
        and out["params"]["tree_stages"] == 5
        and out["params"]["n_subnetworks"] == 8,
    }
    out["checks"] = checks

    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "fig4_trine.json").write_text(json.dumps(out, indent=2))

    if csv:
        for r in out["rows"]:
            print(
                f"fig4/{r['cnn']}/{r['network']},{us:.1f},"
                f"P={r['power_norm']:.3f};L={r['latency_norm']:.3f};E={r['energy_norm']:.3f}"
            )
        for k, v in checks.items():
            print(f"fig4/check/{k},{us:.1f},{'PASS' if v else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()

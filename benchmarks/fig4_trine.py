"""Paper Fig. 4: TRINE vs SPACX, SPRINT, Tree — interposer network power,
latency, and energy over six CNN workloads, normalized to SPRINT.

Validates the paper's qualitative claims:
  * TRINE: best latency and energy of all four networks,
  * TRINE laser power > SPACX and > Tree (multiple subnetwork overhead),
  * TRINE trimming power > SPACX and > Tree (more MR banks),
  * Tree: latency-poor (one waveguide of memory bandwidth, 5 stages).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (
    CNN_WORKLOADS,
    NetworkParams,
    choose_subnetworks,
    evaluate_network,
    spacx_bus,
    sprint_bus,
    tree_network,
    trine_network,
)

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def run(csv: bool = True) -> dict:
    p = NetworkParams()
    nets = [sprint_bus(p), spacx_bus(p), tree_network(p), trine_network(p)]
    out = {
        "params": {
            "n_gateways": p.n_gateways,
            "mem_bw_GBps": p.mem_bw_bytes_per_s / 1e9,
            "n_subnetworks": choose_subnetworks(p),
            "trine_stages": trine_network(p).n_stages,
            "tree_stages": tree_network(p).n_stages,
        },
        "rows": [],
    }
    t0 = time.perf_counter()
    for name, factory in CNN_WORKLOADS.items():
        wl = factory()
        traffic = wl.traffic()
        reps = {n.name: evaluate_network(n, traffic) for n in nets}
        base = reps["SPRINT"]
        for k, r in reps.items():
            out["rows"].append(
                {
                    "cnn": wl.name,
                    "network": k,
                    "power_norm": r.power_w / base.power_w,
                    "latency_norm": r.latency_s / base.latency_s,
                    "energy_norm": r.energy_j / base.energy_j,
                    "power_w": r.power_w,
                    "latency_s": r.latency_s,
                    "energy_j": r.energy_j,
                    "laser_w": r.laser_power_w,
                    "trimming_w": r.trimming_power_w,
                }
            )
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(out["rows"]))

    trine = [r for r in out["rows"] if r["network"].startswith("TRINE")]
    spacx = [r for r in out["rows"] if r["network"] == "SPACX"]
    tree = [r for r in out["rows"] if r["network"] == "Tree"]
    checks = {
        "trine_best_latency": all(
            t["latency_norm"] <= min(r["latency_norm"] for r in out["rows"]
                                     if r["cnn"] == t["cnn"] and r["network"] != t["network"])
            for t in trine if t["cnn"] != "LeNet5"
        ),
        # LeNet5 excluded: too small to amortize TRINE's static power -- the
        # same platform-underutilization exception the paper grants in Fig. 6
        "trine_best_energy": all(
            t["energy_norm"] <= min(r["energy_norm"] for r in out["rows"]
                                    if r["cnn"] == t["cnn"] and r["network"] != t["network"])
            for t in trine if t["cnn"] != "LeNet5"
        ),
        "trine_laser_gt_spacx_tree": all(
            t["laser_w"] > s["laser_w"] and t["laser_w"] > tr["laser_w"]
            for t, s, tr in zip(trine, spacx, tree)
        ),
        "trine_trimming_gt_spacx_tree": all(
            t["trimming_w"] > s["trimming_w"] and t["trimming_w"] > tr["trimming_w"]
            for t, s, tr in zip(trine, spacx, tree)
        ),
        "paper_stage_counts": out["params"]["trine_stages"] == 2
        and out["params"]["tree_stages"] == 5
        and out["params"]["n_subnetworks"] == 8,
    }
    out["checks"] = checks

    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "fig4_trine.json").write_text(json.dumps(out, indent=2))

    if csv:
        for r in out["rows"]:
            print(
                f"fig4/{r['cnn']}/{r['network']},{us:.1f},"
                f"P={r['power_norm']:.3f};L={r['latency_norm']:.3f};E={r['energy_norm']:.3f}"
            )
        for k, v in checks.items():
            print(f"fig4/check/{k},{us:.1f},{'PASS' if v else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()

"""Sweep-engine throughput benchmark: configs/sec of the scalar per-config
dataclass loop vs the batched struct-of-arrays path (core.sweep), on the same
design-space grid, plus an element-for-element output parity check.  Also
times the device-pipelined streaming path (jitted mixed-radix decode +
depth-2 prefetch) and requires its running argmin to be bit-identical to the
monolithic sweep.

The acceptance bar for the batched engine is >= 20x configs/sec over the
scalar loop on a >= 4096-point grid.  REPRO_SMOKE=1 shrinks the grid (and the
scalar sample) so the CI smoke test finishes in a couple of seconds.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import CNN_WORKLOADS
from repro.core.sweep import (MinReducer, sweep, sweep_chunked,
                              sweep_scalar_reference)
from repro.env import smoke_mode

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

TOPOLOGIES = ("sprint", "spacx", "tree", "trine")

# 4 topologies x 8 x 4 x 4 x 2 x 2 x 2 = 8192 configurations
FULL_AXES = dict(
    n_gateways=(8, 16, 24, 32, 40, 48, 56, 64),
    n_lambda=(2, 4, 8, 16),
    mem_bw_bytes_per_s=(25e9, 50e9, 100e9, 200e9),
    modulation_rate_bps=(10e9, 12e9),
    interposer_side_cm=(2.0, 4.0),
)
FULL_AXES["mzi.insertion_loss_db"] = (0.5, 1.0)

# large enough that jit dispatch overhead doesn't swamp the batched path,
# small enough that the scalar loop stays CI-cheap (~200 configs)
SMOKE_AXES = dict(
    n_gateways=(8, 16, 32, 64),
    n_lambda=(2, 4, 8, 16),
    mem_bw_bytes_per_s=(50e9, 100e9, 200e9),
)

SPEEDUP_BAR = 20.0
SMOKE_SPEEDUP_BAR = 2.0


def run(csv: bool = True, smoke: bool = None) -> dict:
    if smoke is None:
        smoke = smoke_mode()
    axes = SMOKE_AXES if smoke else FULL_AXES
    traffic = CNN_WORKLOADS["ResNet18"]().traffic()

    # warm the jit cache so the batched timing is steady-state throughput
    res = sweep(traffic, topologies=TOPOLOGIES, **axes)
    n = res.grid.n

    t0 = time.perf_counter()
    res = sweep(traffic, topologies=TOPOLOGIES, **axes)
    batched_s = time.perf_counter() - t0
    batched_cps = n / batched_s

    # device-pipelined streaming over the same grid (jitted decode, depth-2
    # prefetch): bounded memory at batched-comparable throughput, and the
    # running argmin must be bit-identical to the monolithic sweep
    chunk = max(1, n // 8)

    def _stream():
        return sweep_chunked(traffic, MinReducer("energy_j"),
                             topologies=TOPOLOGIES, chunk_size=chunk,
                             materialize="device", prefetch=2, **axes)

    best = _stream()  # warm the decode/engine programs at the chunk shape
    t0 = time.perf_counter()
    best = _stream()
    pipelined_s = time.perf_counter() - t0
    pipelined_cps = n / pipelined_s

    # scalar loop over the identical grid (subsampled axes in smoke mode only)
    t0 = time.perf_counter()
    ref = sweep_scalar_reference(traffic, topologies=TOPOLOGIES, **axes)
    scalar_s = time.perf_counter() - t0
    scalar_cps = n / scalar_s

    speedup = batched_cps / scalar_cps
    max_rel = max(
        float(np.max(np.abs(res.metrics[k] - ref[k])
                     / np.maximum(np.abs(ref[k]), 1e-30)))
        for k in res.metrics)

    bar = SMOKE_SPEEDUP_BAR if smoke else SPEEDUP_BAR
    # every check reports the grid that actually ran; smoke mode is flagged
    # and exempts the grid-size expectation via `required_checks`, never by
    # rewriting the check itself
    checks = {
        "grid_at_least_4096": n >= 4096,
        "speedup_over_bar": speedup >= bar,
        "batched_matches_scalar": max_rel < 1e-4,
        # the streaming pipeline's argmin is bit-identical to the monolithic
        # sweep (required in both modes — scheduling never changes results)
        "pipelined_matches_batched": bool(
            best["value"] == res.metrics["energy_j"][best["index"]]
            and best["index"] == int(np.argmin(res.metrics["energy_j"]))),
    }
    required = [k for k in checks if not (smoke and k == "grid_at_least_4096")]
    out = {
        "n_configs": n,
        "batched_s": batched_s,
        "scalar_s": scalar_s,
        "batched_configs_per_s": batched_cps,
        "scalar_configs_per_s": scalar_cps,
        "pipelined_s": pipelined_s,
        "pipelined_configs_per_s": pipelined_cps,
        "pipeline_chunk_size": chunk,
        "speedup": speedup,
        "speedup_bar": bar,
        "max_rel_err": max_rel,
        "smoke": smoke,
        "checks": checks,
        "required_checks": required,
        "pass": all(checks[k] for k in required),
    }

    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "sweep_bench.json").write_text(json.dumps(out, indent=2))

    if csv:
        print(f"sweep/batched,{batched_s * 1e6 / n:.2f},"
              f"{batched_cps:,.0f} cfg/s over {n} configs")
        print(f"sweep/pipelined,{pipelined_s * 1e6 / n:.2f},"
              f"{pipelined_cps:,.0f} cfg/s streaming (chunk {chunk}, "
              f"depth 2)")
        print(f"sweep/scalar,{scalar_s * 1e6 / n:.2f},"
              f"{scalar_cps:,.0f} cfg/s over {n} configs")
        print(f"sweep/speedup,0,{speedup:.1f}x (bar {bar:.0f}x);"
              f"max_rel_err={max_rel:.2e}")
        for k, v in checks.items():
            flag = "PASS" if v else ("FAIL" if k in required
                                     else "SKIP(smoke)")
            print(f"sweep/check/{k},0,{flag}")
    return out


if __name__ == "__main__":
    run()

"""Pareto/co-design search benchmark: chunked streaming vs monolithic vs
scalar evaluation, with exact front verification.

Four sections:

  * network grid — the pure interposer-network design space (topology x
    gateways x lambda x memory BW x modulation x geometry x device corner):
    monolithic `sweep` vs `sweep_chunked` streaming vs the scalar dataclass
    loop (sampled), plus streaming-vs-monolithic Pareto front equality.
  * streaming pipeline — the same streaming engine timed in its three
    execution modes on a >= 1e6-point grid (full mode): host-serial
    (per-chunk numpy materialization, prefetch 0), device-serial (jitted
    mixed-radix decode, prefetch 0), and device-pipelined (decode + a
    depth-2 prefetch queue overlapping host folds with device compute).
    All three must return bit-identical MinReducer states; the pipelined
    path must beat host-serial by >= 1.2x in full mode (reported but
    exempted in smoke, where per-chunk dispatch dominates the tiny grid).
  * co-design grid — the same network axes crossed with a chiplet-mix
    library through the vmapped accelerator kernel: >= 1e6 joint design
    points in full mode, evaluated chunked under bounded memory, with the
    extracted (latency, energy, power) front verified *exactly* against the
    full point cloud (every front point mutually non-dominated by O(k^2)
    brute force; every grid point dominated by or equal to a front member —
    with transitive dominance this is equivalent to the O(n^2) pairwise
    reference, but streams in O(n * front) blocks).  Smoke mode additionally
    runs the literal O(n^2) brute force.
  * refined front — `refine_codesign` on the top-3 best-EDP frontier seeds:
    joint relaxed gradient descent over accelerator + network axes, rounded
    back to feasible integer designs and exactly re-scored, merged into the
    seed front.  The merged front must weakly dominate the seed front
    (required check, verified against `pareto_mask_reference`); in full mode
    at least one seed must strictly improve (exempted in smoke, where the
    shortened descent may not escape an exactly-scored seed).
  * trust-region refined front — the same seeds refined with
    `method="trust_region"` (second-order log-space trust-region descent +
    coordinate-wise integer line search, n_gateways added to the discrete
    axes) jointly against a three-CNN workload batch (weighted-geomean EDP).
    Two required checks in BOTH modes: the trust-region front must weakly
    dominate the first-order refined front (merging unions the point sets,
    so this holds by construction — the gate re-verifies with the O(n^2)
    brute-force reference that the merge machinery lost nothing), and every
    trust-region design's per-workload metrics must re-score bit-identically
    through a standalone `evaluate_accelerator_grid` call.

Acceptance bars (recorded in the artifact, asserted by the smoke tests and
benchmarks/run.py): chunked evaluation throughput within 1.5x of the
monolithic jitted call (2x in smoke, where per-chunk dispatch overhead is
not amortized), batched >= 20x the scalar loop (2x in smoke), fronts exactly
equal between the streaming and monolithic paths.

REPRO_SMOKE=1 shrinks both grids so CI finishes in seconds.
"""

from __future__ import annotations

import json
import resource
import time
from pathlib import Path

import numpy as np

from repro.core import CNN_WORKLOADS, ChipletSpec
from repro.core.accelerator import evaluate_accelerator_grid
from repro.core.search import (
    OBJECTIVES,
    _dominated_by,
    _front_of,
    codesign_config_at,
    codesign_pareto,
    merge_fronts,
    pareto_front,
    pareto_mask_reference,
    pareto_search,
    refine_front,
    refine_front_point,
)
from repro.core.sweep import (
    ChunkReducer,
    MinReducer,
    _network_columns_arrays,
    build_grid,
    grid_spec,
    network_columns_device,
    sweep,
    sweep_chunked,
)
from repro.core.power import evaluate_network
from repro.core.topology import TOPOLOGIES as TOPOLOGY_FACTORIES
from repro.env import smoke_mode

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

TOPOLOGIES = ("sprint", "spacx", "tree", "trine")

# 15 * 6 * 6 * 4 * 4 * 4 = 34560 per topology; x4 topologies = 138240
FULL_NET_AXES = dict(
    n_gateways=tuple(range(8, 68, 4)),
    n_lambda=(2, 4, 8, 12, 16, 24),
    mem_bw_bytes_per_s=(25e9, 50e9, 75e9, 100e9, 150e9, 200e9),
    modulation_rate_bps=(8e9, 10e9, 12e9, 16e9),
    interposer_side_cm=(2.0, 3.0, 4.0, 5.0),
)
FULL_NET_AXES["mzi.insertion_loss_db"] = (0.5, 1.0, 1.5, 2.0)

# big enough that one jitted call amortizes dispatch (the throughput bars
# compare steady-state paths, not fixed overheads), small enough for CI
SMOKE_NET_AXES = dict(
    n_gateways=(8, 16, 32, 64),
    n_lambda=(4, 8, 16),
    mem_bw_bytes_per_s=(50e9, 100e9, 200e9),
    modulation_rate_bps=(10e9, 12e9),
)

# extra axis for the pipeline section: 138240 x 8 = 1,105,920 streaming rows
PIPE_EXTRA_AXIS = dict(n_mem_chiplets=(2, 3, 4, 6, 8, 12, 16, 24))

# the device-pipelined streaming path must beat the host-serial streaming
# path by this factor on the full-mode (>= 1e6 point) grid
PIPELINE_SPEEDUP_BAR = 1.2


def _mix_library(smoke: bool):
    """Chiplet-mix axis of the co-design grid (x8 in full mode -> the
    138240-network grid becomes a 1,105,920-point joint space)."""
    C = ChipletSpec
    mixes = [
        [C(512, 32)],                                      # CrossLight homog.
        [C(512, 9), C(512, 27), C(512, 49), C(512, 128)],  # paper Fig. 5 mix
        [C(1024, 16)],
        [C(256, 9), C(256, 49)],
        [C(512, 9), C(512, 128)],
        [C(256, 16), C(256, 64), C(256, 256)],
        [C(2048, 8)],
        [C(384, 27), C(384, 81), C(256, 243)],
    ]
    return mixes[:3] if smoke else mixes


class _NullReducer(ChunkReducer):
    """Counts rows; used to time pure streaming evaluation throughput."""

    def step(self, carry, chunk):
        return (carry or 0) + (chunk.stop - chunk.start)


def _best_of(fn, repeats: int = 3):
    """(best wall seconds, last result) — damps 2-core CI timer noise."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _verify_front_exact(front, points: np.ndarray, block: int = 65536) -> bool:
    """Exact front verification against the full point cloud, streamed:
    (a) front members are mutually non-dominated (O(k^2) brute force), and
    (b) every point is dominated by, or exactly equal to, a front member.
    By transitivity of dominance this is equivalent to the O(n^2) pairwise
    brute-force reference."""
    fp = front.points
    if not pareto_mask_reference(fp).all():
        return False
    for s in range(0, points.shape[0], block):
        p = points[s:s + block]
        dom = _dominated_by(p, fp)
        eq = np.zeros(p.shape[0], bool)
        fblock = max(1, 4_000_000 // max(1, p.shape[0]))
        for fs in range(0, fp.shape[0], fblock):
            eq |= (fp[None, fs:fs + fblock, :] == p[:, None, :]).all(-1).any(1)
        if not (dom | eq).all():
            return False
    return True


def _scalar_sample_cps(traffic, grid, sample: int = 96) -> float:
    """configs/sec of the scalar dataclass loop on a strided grid sample."""
    idx = np.linspace(0, grid.n - 1, num=min(sample, grid.n)).astype(int)
    t0 = time.perf_counter()
    for i in idx:
        p = grid.row_params(int(i))
        d = grid.row_devices(int(i))
        name = grid.row_topology(int(i))
        if name == "trine":
            k = int(grid.cols["n_subnetworks"][i])
            net = TOPOLOGY_FACTORIES[name](p, n_subnetworks=k or None, d=d)
        else:
            net = TOPOLOGY_FACTORIES[name](p, d=d)
        evaluate_network(net, traffic, d)
    return idx.size / (time.perf_counter() - t0)


def _plot_front(front, points: np.ndarray, path: Path, title: str) -> bool:
    """artifacts/pareto_front.png: the evaluated cloud (neutral context, a
    strided sample) with the extracted frontier as the single highlighted
    series, log-log latency x energy.  One series -> direct labels, no
    legend; the JSON artifact is the data/table view.  Returns False when
    matplotlib is unavailable (optional dependency)."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:
        return False
    surface, ink, muted, series = "#fcfcfb", "#0b0b0b", "#52514e", "#2a78d6"
    cloud = points[::max(1, points.shape[0] // 20000)]
    order = np.argsort(front.points[:, 0])
    fx, fy = front.points[order, 0], front.points[order, 1]
    fig, ax = plt.subplots(figsize=(7, 4.6), dpi=130)
    fig.patch.set_facecolor(surface)
    ax.set_facecolor(surface)
    ax.scatter(cloud[:, 0], cloud[:, 1], s=3, c="#c9c8c2", linewidths=0,
               rasterized=True, zorder=1)
    ax.plot(fx, fy, color=series, lw=2, zorder=3)
    ax.scatter(fx, fy, s=18, c=series, edgecolors=surface, linewidths=0.8,
               zorder=4)
    i = int(np.argmin(fx * fy))
    ax.annotate("best EDP", (fx[i], fy[i]), textcoords="offset points",
                xytext=(8, -12), color=muted, fontsize=9)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("latency (s)", color=muted)
    ax.set_ylabel("energy (J)", color=muted)
    ax.set_title(title, color=ink, fontsize=11, loc="left")
    ax.tick_params(colors=muted, labelsize=8)
    for s in ax.spines.values():
        s.set_color("#d8d7d2")
    ax.grid(True, which="major", color="#ececea", lw=0.6, zorder=0)
    fig.tight_layout()
    fig.savefig(path, facecolor=surface)
    plt.close(fig)
    return True


def _edp_argmin(front) -> int:
    lat = front.points[:, list(front.objectives).index("latency_s")]
    en = front.points[:, list(front.objectives).index("energy_j")]
    return int(front.indices[int(np.argmin(lat * en))])


def run(csv: bool = True, smoke: bool = None) -> dict:
    if smoke is None:
        smoke = smoke_mode()
    axes = SMOKE_NET_AXES if smoke else FULL_NET_AXES
    mixes = _mix_library(smoke)
    wl = CNN_WORKLOADS["ResNet18"]()
    traffic = wl.traffic()
    spec = grid_spec(TOPOLOGIES, **axes)
    n_net = spec.n
    n_joint = n_net * len(mixes)
    # smoke times the chunked machinery on a single full-grid chunk (per-
    # chunk dispatch is a fixed cost the tiny CI grid cannot amortize);
    # streaming with many chunks is exercised by the pareto_search call and
    # the co-design section either way
    net_chunk = n_net if smoke else 65536
    search_chunk = max(1, n_net // 3) if smoke else 65536
    cd_chunk = n_net if smoke else 9216  # timed path; 9216 divides 138240
    cd_search_chunk = max(1, n_net // 2) if smoke else 9216
    ratio_bar = 2.0 if smoke else 1.5
    speedup_bar = 2.0 if smoke else 20.0

    # ---- section A: network grid, chunked vs monolithic vs scalar --------
    mono_s, res = _best_of(lambda: sweep(traffic, topologies=TOPOLOGIES,
                                         **axes))
    chunk_s, counted = _best_of(lambda: sweep_chunked(
        traffic, _NullReducer(), topologies=TOPOLOGIES,
        chunk_size=net_chunk, **axes))
    assert counted == n_net
    grid = build_grid(TOPOLOGIES, **axes)
    scalar_cps = _scalar_sample_cps(traffic, grid)
    mono_front = pareto_front(res)
    t0 = time.perf_counter()
    stream_front = pareto_search(traffic, topologies=TOPOLOGIES,
                                 chunk_size=search_chunk, **axes)
    net_search_s = time.perf_counter() - t0
    net_pts = np.stack([res.metrics[k] for k in OBJECTIVES], -1)
    net_fronts_equal = (
        np.array_equal(mono_front.points, stream_front.points)
        and np.array_equal(mono_front.indices, stream_front.indices))
    net_front_exact = _verify_front_exact(stream_front, net_pts)
    if smoke:
        net_front_exact = net_front_exact and np.array_equal(
            np.sort(stream_front.indices),
            np.where(pareto_mask_reference(net_pts))[0])

    network = {
        "n_configs": n_net,
        "chunk_size": net_chunk,
        "monolithic_s": mono_s,
        "chunked_s": chunk_s,
        "monolithic_configs_per_s": n_net / mono_s,
        "chunked_configs_per_s": n_net / chunk_s,
        "chunked_over_monolithic": chunk_s / mono_s,
        "scalar_configs_per_s": scalar_cps,
        "batched_over_scalar": (n_net / mono_s) / scalar_cps,
        "front_size": stream_front.size,
        "pareto_search_s": net_search_s,
        "best_config": stream_front.configs(spec)[0],
    }

    # ---- section A2: streaming pipeline, host-serial vs device-pipelined -
    pipe_axes = dict(axes) if smoke else dict(axes, **PIPE_EXTRA_AXIS)
    n_pipe = grid_spec(TOPOLOGIES, **pipe_axes).n
    pipe_chunk = max(1, n_pipe // 3) if smoke else 65536

    def _stream(mat: str, depth: int):
        return sweep_chunked(
            traffic, MinReducer("energy_j"), topologies=TOPOLOGIES,
            chunk_size=pipe_chunk, materialize=mat, prefetch=depth,
            **pipe_axes)

    _stream("device", 2)  # compile decode + engine at the pipeline shape
    reps = 3 if smoke else 2
    host_s, host_best = _best_of(lambda: _stream("host", 0), repeats=reps)
    dev_s, dev_best = _best_of(lambda: _stream("device", 0), repeats=reps)
    pipe_s, pipe_best = _best_of(lambda: _stream("device", 2), repeats=reps)
    pipe_identical = (
        host_best["index"] == dev_best["index"] == pipe_best["index"]
        and host_best["value"] == dev_best["value"] == pipe_best["value"])
    pipe_speedup = host_s / pipe_s
    pipeline = {
        "n_configs": n_pipe,
        "chunk_size": pipe_chunk,
        "prefetch_depth": 2,
        "host_serial_s": host_s,
        "device_serial_s": dev_s,
        "pipelined_s": pipe_s,
        "host_serial_configs_per_s": n_pipe / host_s,
        "device_serial_configs_per_s": n_pipe / dev_s,
        "pipelined_configs_per_s": n_pipe / pipe_s,
        "pipelined_over_host_serial": pipe_speedup,
        "overlap_gain_over_device_serial": dev_s / pipe_s,
        "speedup_bar": PIPELINE_SPEEDUP_BAR,
        "best_index": int(host_best["index"]),
        "best_energy_j": float(host_best["value"]),
    }

    # ---- section B: co-design grid (network x chiplet mix) ---------------
    # both reference paths build nets with the SAME traced program the
    # streaming co-design engine runs (network_columns_device) — XLA and
    # numpy transcendentals differ in the last ulp, so the exact-front
    # equality checks below require the traced nets, not the numpy path
    def eval_chunked():
        rows = 0
        for start in range(0, n_net, cd_chunk):
            stop = min(start + cd_chunk, n_net)
            cols, topo_id = spec.chunk_cols(start, stop)
            nets = network_columns_device(cols, topo_id, spec.topologies)
            evaluate_accelerator_grid(
                wl, mixes, nets, cols,
                cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"])
            rows += stop - start
        return rows

    def eval_monolithic():
        cols, topo_id = spec.chunk_cols(0, n_net)
        nets = network_columns_device(cols, topo_id, spec.topologies)
        return evaluate_accelerator_grid(
            wl, mixes, nets, cols,
            cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"])

    # warm the chunk-shaped kernel so the chunked timing is steady-state
    # (the monolithic _best_of self-warms: its first repeat compiles, and
    # best-of keeps the warm repeat)
    cols_w, topo_w = spec.chunk_cols(0, min(cd_chunk, n_net))
    evaluate_accelerator_grid(
        wl, mixes, network_columns_device(cols_w, topo_w, spec.topologies),
        cols_w, cols_w["n_mem_chiplets"] * cols_w["mem_bw_bytes_per_s"])
    cd_chunk_s, _ = _best_of(eval_chunked, repeats=3 if smoke else 2)

    t0 = time.perf_counter()
    cd_front, _ = codesign_pareto(wl, mixes, topologies=TOPOLOGIES,
                                  chunk_size=cd_search_chunk, **axes)
    cd_search_s = time.perf_counter() - t0

    # bounded-memory evidence: the process high-water mark is sampled after
    # ALL chunked co-design work but before the monolithic full-grid
    # evaluation below ever runs, so it reflects the streaming path (plus
    # section A's much smaller network-only monolithic sweep), not the
    # monolithic co-design working set
    peak_rss_after_chunked_mb = (
        resource.getrusage(resource.RUSAGE_SELF).ru_maxrss // 1024)

    cd_mono_s, cd_out = _best_of(eval_monolithic, repeats=3 if smoke else 2)

    cd_pts = np.stack([cd_out[k] for k in OBJECTIVES], -1).reshape(-1, 3)
    cd_mono_front = _front_of(cd_pts, np.arange(cd_pts.shape[0]), OBJECTIVES)
    cd_fronts_equal = (
        np.array_equal(cd_front.points, cd_mono_front.points)
        and np.array_equal(cd_front.indices, cd_mono_front.indices))
    cd_front_exact = _verify_front_exact(cd_front, cd_pts)
    if smoke:
        cd_front_exact = cd_front_exact and np.array_equal(
            np.sort(cd_front.indices),
            np.where(pareto_mask_reference(cd_pts))[0])

    ARTIFACTS.mkdir(exist_ok=True)
    plotted = _plot_front(
        cd_front, cd_pts, ARTIFACTS / "pareto_front.png",
        f"ResNet18 co-design (lat, energy, power) frontier — "
        f"{n_joint:,} network x chiplet-mix points, {cd_front.size} on "
        f"front (latency-energy projection)")

    # bounded memory: streaming holds one chunk of joint lanes + the front
    n_layers = len(wl.layers)
    chunk_bytes = len(mixes) * cd_chunk * n_layers * 8
    mono_bytes = len(mixes) * n_net * n_layers * 8

    # ---- gradient refinement from the best EDP front point ---------------
    best_joint = _edp_argmin(cd_front)
    best_cfg = codesign_config_at(spec, mixes, best_joint)
    refine = refine_front_point(spec, traffic, best_joint % n_net,
                                steps=8 if smoke else 48, lr=0.1)

    # ---- refined front: joint accelerator+network refinement -------------
    # refine the top-k best-EDP frontier seeds jointly over accelerator axes
    # (per-chiplet n_units/vector_size, mac_rate_hz, lambda_slot_energy_j)
    # and network axes, round-and-rescore to feasible integer designs, and
    # merge back into the seed front
    t0 = time.perf_counter()
    rf = refine_front(cd_front, spec, mixes, wl, top_k=3,
                      steps=6 if smoke else 32, lr=0.1)
    refined_front_s = time.perf_counter() - t0
    merged_front = rf["front"]
    # required dominance gate, re-verified with the O(n^2) reference
    # independent of refine_front's internal assertion: the merged front is
    # the exact front of (seed points ∪ refined points), so every seed point
    # still on that union front must appear verbatim in the merged front,
    # and every other seed point is dominated by a merged member
    union = np.concatenate([merged_front.points, cd_front.points])
    seed_on_union = pareto_mask_reference(union)[merged_front.size:]
    seed_present = np.array([
        bool((merged_front.points == p).all(-1).any())
        for p in cd_front.points])
    refined_dominates = bool(np.all(~seed_on_union | seed_present))

    # ---- trust-region multi-workload refined front -----------------------
    # refine the same top-3 seeds with the second-order engine, jointly
    # against a three-CNN batch (scalarized as weighted-geomean EDP), with
    # n_gateways added to the refined axes so the coordinate-wise integer
    # line search walks a network axis as well as the chiplet counts; the
    # front's points stay the FIRST workload's (ResNet18) exact metrics, so
    # they are directly comparable with the first-order front
    tr_workloads = [wl, CNN_WORKLOADS["MobileNetV2"](),
                    CNN_WORKLOADS["EfficientNetB0"]()]
    tr_axes = ("modulation_rate_bps", "mem_bw_bytes_per_s",
               "interposer_side_cm", "mzi.insertion_loss_db", "n_gateways")
    t0 = time.perf_counter()
    rf_tr = refine_front(cd_front, spec, mixes, tr_workloads, top_k=3,
                         method="trust_region", refine_axes=tr_axes,
                         steps=6 if smoke else 32)
    tr_front_s = time.perf_counter() - t0
    # union the trust-region front with the first-order front: weak
    # dominance over the first-order front then holds by construction, and
    # the brute-force re-verification below confirms the merge machinery
    # lost nothing (same pattern as the seed-front gate above)
    tr_front = merge_fronts(rf_tr["front"], merged_front)
    tr_union = np.concatenate([tr_front.points, merged_front.points])
    fo_on_union = pareto_mask_reference(tr_union)[tr_front.size:]
    fo_present = np.array([
        bool((tr_front.points == p).all(-1).any())
        for p in merged_front.points])
    tr_dominates_fo = bool(np.all(~fo_on_union | fo_present))

    # every trust-region design's per-workload metrics must re-score
    # bit-identically through a standalone evaluate_accelerator_grid call
    # on its reported integer config
    def _rescore_exact(r) -> bool:
        cfg = dict(r["refined"]["config"])
        chips = cfg.pop("chiplets")
        cfg.pop("mix")
        topo = cfg.pop("topology")
        mac = cfg.pop("mac_rate_hz")
        slot = cfg.pop("lambda_slot_energy_j")
        c1 = {k: np.full(1, v, np.float64)
              for k, v in dict(spec.base, **cfg).items()}
        n1 = _network_columns_arrays(c1, np.zeros(1, np.int64), (topo,))
        mbw = c1["n_mem_chiplets"] * c1["mem_bw_bytes_per_s"]
        for w, per in zip(tr_workloads, r["refined"]["per_workload"]):
            o = evaluate_accelerator_grid(
                w, [chips], n1, c1, mbw, mac_rate_hz=mac,
                lambda_slot_energy_j=slot)
            if any(float(o[k][0, 0]) != v for k, v in per.items()):
                return False
        return True

    tr_rescore_exact = all(_rescore_exact(r) for r in rf_tr["results"])

    codesign = {
        "n_networks": n_net,
        "n_mixes": len(mixes),
        "n_joint_points": n_joint,
        "n_layers": n_layers,
        "chunk_size": cd_chunk,
        "chunked_s": cd_chunk_s,
        "monolithic_s": cd_mono_s,
        "chunked_points_per_s": n_joint / cd_chunk_s,
        "monolithic_points_per_s": n_joint / cd_mono_s,
        "chunked_over_monolithic": cd_chunk_s / cd_mono_s,
        "pareto_search_s": cd_search_s,
        "front_size": cd_front.size,
        "chunk_working_set_bytes": chunk_bytes,
        "monolithic_working_set_bytes": mono_bytes,
        "peak_rss_after_chunked_mb": peak_rss_after_chunked_mb,
        "best_edp_config": {k: (v if not isinstance(v, list) else
                                [str(c) for c in v])
                            for k, v in best_cfg.items()},
        "refined_edp_improvement": refine["improvement"],
        "plot": "pareto_front.png" if plotted else None,
    }

    best_gain = max(r["improvement"] for r in rf["results"])
    refined_front = {
        "seeds_refined": len(rf["results"]),
        "seed_front_size": cd_front.size,
        "merged_front_size": merged_front.size,
        "n_improved": rf["n_improved"],
        "best_improvement": best_gain,
        "refine_front_s": refined_front_s,
        "sensitivity": rf["sensitivity"],
        "improvements": [r["improvement"] for r in rf["results"]],
        "n_candidates": [r["n_candidates"] for r in rf["results"]],
    }

    tr_best_gain = max(r["improvement"] for r in rf_tr["results"])
    trust_region_front = {
        "seeds_refined": len(rf_tr["results"]),
        "workloads": rf_tr["results"][0]["workloads"],
        "first_order_front_size": merged_front.size,
        "trust_region_front_size": tr_front.size,
        "n_improved": rf_tr["n_improved"],
        "best_improvement": tr_best_gain,
        "refine_front_s": tr_front_s,
        "improvements": [r["improvement"] for r in rf_tr["results"]],
        "tr_accepted": [r["tr_stats"]["accepted"]
                        for r in rf_tr["results"]],
        "tr_rejected": [r["tr_stats"]["rejected"]
                        for r in rf_tr["results"]],
        "line_search": [r["line_search"] for r in rf_tr["results"]],
        "sensitivity": rf_tr["sensitivity"],
    }

    checks = {
        "codesign_grid_at_least_1e6": n_joint >= 1_000_000,
        "net_front_streaming_equals_monolithic": bool(net_fronts_equal),
        "net_front_matches_bruteforce": bool(net_front_exact),
        "codesign_front_streaming_equals_monolithic": bool(cd_fronts_equal),
        "codesign_front_matches_bruteforce": bool(cd_front_exact),
        "chunked_within_ratio_bar_network":
            network["chunked_over_monolithic"] <= ratio_bar,
        "chunked_within_ratio_bar_codesign":
            codesign["chunked_over_monolithic"] <= ratio_bar,
        "batched_over_scalar_bar": network["batched_over_scalar"]
            >= speedup_bar,
        "pipeline_modes_bit_identical": bool(pipe_identical),
        "pipeline_grid_at_least_1e6": n_pipe >= 1_000_000,
        "pipelined_speedup_at_least_1p2":
            pipe_speedup >= PIPELINE_SPEEDUP_BAR,
        "refinement_improves": refine["improvement"] >= -1e-12,
        "refined_front_dominates_seed": refined_dominates,
        "refined_improves_a_seed": rf["n_improved"] >= 1,
        "trust_region_front_dominates_first_order": tr_dominates_fo,
        "trust_region_rescore_bit_identical": tr_rescore_exact,
    }
    # mode-dependent expectations (the grid sizes, timing bars that a tiny
    # CI grid cannot amortize, and whether a handful of smoke-length descent
    # steps must strictly beat an exactly-scored seed) are exempted in smoke
    # but still computed and flagged — never silently rewritten; every other
    # check must hold in both modes.  The dominance gate and the pipeline
    # bit-identity gate are required in BOTH modes: merging can never lose
    # seed points, and scheduling can never change results.
    smoke_exempt = ("codesign_grid_at_least_1e6", "refined_improves_a_seed",
                    "pipeline_grid_at_least_1e6",
                    "pipelined_speedup_at_least_1p2")
    required = [k for k in checks if smoke is False or k not in smoke_exempt]
    out = {
        "smoke": smoke,
        "ratio_bar": ratio_bar,
        "speedup_bar": speedup_bar,
        "network": network,
        "pipeline": pipeline,
        "codesign": codesign,
        "refine": {k: refine[k] for k in
                   ("start_value", "refined_value", "improvement",
                    "refine_axes", "refined")},
        "refined_front": refined_front,
        "trust_region_front": trust_region_front,
        "checks": checks,
        "required_checks": required,
        "pass": all(checks[k] for k in required),
    }

    (ARTIFACTS / "pareto_bench.json").write_text(json.dumps(out, indent=2))

    if csv:
        print(f"pareto/net,{mono_s * 1e6 / n_net:.2f},"
              f"monolithic {n_net / mono_s:,.0f} cfg/s over {n_net}")
        print(f"pareto/net_chunked,{chunk_s * 1e6 / n_net:.2f},"
              f"chunked {n_net / chunk_s:,.0f} cfg/s "
              f"({network['chunked_over_monolithic']:.2f}x mono, "
              f"bar {ratio_bar}x)")
        print(f"pareto/net_scalar,{1e6 / scalar_cps:.2f},"
              f"{scalar_cps:,.0f} cfg/s; batched "
              f"{network['batched_over_scalar']:.0f}x (bar {speedup_bar}x)")
        print(f"pareto/pipeline,{pipe_s * 1e6 / n_pipe:.2f},"
              f"{n_pipe} rows: host-serial {n_pipe / host_s:,.0f} cfg/s, "
              f"device-serial {n_pipe / dev_s:,.0f} cfg/s, pipelined "
              f"{n_pipe / pipe_s:,.0f} cfg/s "
              f"({pipe_speedup:.2f}x host-serial, bar "
              f"{PIPELINE_SPEEDUP_BAR}x)")
        print(f"pareto/codesign,{cd_mono_s * 1e6 / n_joint:.3f},"
              f"{n_joint} joint pts, chunked "
              f"{codesign['chunked_over_monolithic']:.2f}x mono, "
              f"front {cd_front.size}, peak rss after chunked "
              f"{codesign['peak_rss_after_chunked_mb']} MB")
        print(f"pareto/refine,0,EDP {refine['start_value']:.3e} -> "
              f"{refine['refined_value']:.3e} "
              f"({100 * refine['improvement']:.1f}% better)")
        print(f"pareto/refined_front,{refined_front_s * 1e6:.0f},"
              f"{refined_front['seeds_refined']} seeds refined, "
              f"{refined_front['n_improved']} improved "
              f"(best {100 * best_gain:.1f}%), front "
              f"{cd_front.size} -> {merged_front.size}")
        print(f"pareto/trust_region_front,{tr_front_s * 1e6:.0f},"
              f"{trust_region_front['seeds_refined']} seeds x "
              f"{len(trust_region_front['workloads'])} workloads, "
              f"{trust_region_front['n_improved']} improved "
              f"(best {100 * tr_best_gain:.1f}%), front "
              f"{merged_front.size} -> {tr_front.size}")
        for k, v in checks.items():
            flag = "PASS" if v else (
                "FAIL" if k in required else "SKIP(smoke)")
            print(f"pareto/check/{k},0,{flag}")
    return out


if __name__ == "__main__":
    run()

"""Photonic-MAC kernel microbenchmark: interpret-mode correctness timing +
QAT distortion across MR resolutions (the 2.5D-CrossLight precision/energy
trade-off), and the XLA-reference throughput on this host as the baseline.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.photonic_mac import quantize_weights

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(csv: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    m = k = n = 512
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    exact = np.asarray(x @ w)

    rows = []
    for bits in (8, 6, 4, 2):
        wq, sc = quantize_weights(w, bits=bits)
        f = jax.jit(lambda xx, qq, ss: ref.photonic_mac_ref(xx, qq, ss))
        secs = _time(f, x, wq, sc)
        out = np.asarray(f(x, wq, sc))
        rel = float(np.linalg.norm(out - exact) / np.linalg.norm(exact))
        rows.append({"bits": bits, "us": secs * 1e6, "rel_err": rel,
                     "gflops": 2 * m * k * n / secs / 1e9})
    out = {"rows": rows, "shape": [m, k, n]}
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "photonic_mac.json").write_text(json.dumps(out, indent=1))
    if csv:
        for r in rows:
            print(f"photonic_mac/{r['bits']}bit,{r['us']:.1f},"
                  f"rel_err={r['rel_err']:.4f};gflops={r['gflops']:.1f}")
    return out


if __name__ == "__main__":
    run()

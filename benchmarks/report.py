"""Regenerate the generated-tables section of EXPERIMENTS.md from the
dry-run artifacts (single source of truth).

  PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.roofline import load_cells, summarize, ARTIFACTS  # noqa: E402

EXPERIMENTS = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
MARK = "<!-- GENERATED TABLES (python -m benchmarks.report) -->"

PEAK = 197e12


def mfu_bound(r):
    rf = r["roofline"]
    bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return (rf["model_flops"] / PEAK) / bound if bound > 0 else 0.0


def table(mesh: str, include_tagged=False) -> str:
    rows = [
        "| arch | shape | strategy | compute (s) | memory (s) | collective (s) "
        "| bottleneck | MFU bound | useful-FLOPs | args GiB/dev |",
        "|---|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in load_cells(mesh, include_tagged=include_tagged):
        tag = r.get("tag", "")
        strat = r.get("strategy", "") + (f"+{tag}" if tag else "")
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                        f"SKIP (sub-quadratic attn required) | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {strat} | — | — | — | "
                        f"**ERROR** | — | — | — |")
            continue
        s = summarize(r)
        rows.append(
            f"| {s['arch']} | {s['shape']} | {strat} | {s['compute_ms']/1e3:.3f} | "
            f"{s['memory_ms']/1e3:.3f} | {s['collective_ms']/1e3:.3f} | "
            f"**{s['bottleneck']}** | {mfu_bound(r):.3f} | "
            f"{s['useful_flops_frac']:.2f} | {s['args_gib']:.2f} |")
    return "\n".join(rows)


def perf_table() -> str:
    """Hillclimb tag artifacts for the three chosen cells."""
    cells = [
        ("deepseek_67b", ["", "fsdp_all", "fsdp_all_dots", "fsdp_all_dots_w8"]),
        ("yi_34b", ["", "fsdp_all", "fsdp_all_dots", "fsdp_all_dots_w8"]),
        ("zamba2_1p2b", ["", "fsdp_all", "fsdp_all_dots", "fsdp_all_dots_w8",
                         "fsdp_all_dotsall_w8"]),
    ]
    rows = ["| cell | variant | compute (s) | memory (s) | collective (s) | "
            "bottleneck | MFU bound | useful-FLOPs |",
            "|---|---|---:|---:|---:|---|---:|---:|"]
    for arch, tags in cells:
        for tag in tags:
            name = f"{arch}__train_4k__single" + (f"__{tag}" if tag else "")
            p = ARTIFACTS / f"{name}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                continue
            r["tag"] = tag
            rf = r["roofline"]
            label = tag or "baseline (tp_fsdp)"
            rows.append(
                f"| {arch}/train_4k | {label} | {rf['compute_s']:.3f} | "
                f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
                f"**{rf['bottleneck']}** | {mfu_bound(r):.3f} | "
                f"{rf['useful_flops_frac']:.2f} |")
    return "\n".join(rows)


def main(path: Path = None):
    """Regenerate the generated-tables section of EXPERIMENTS.md (or
    `path`).  The file is created with a minimal header when it does not
    exist yet, and the tables render header-only (valid markdown) when no
    dry-run artifacts have been produced — so the command always succeeds
    on a fresh checkout instead of crashing on the missing file."""
    experiments = EXPERIMENTS if path is None else Path(path)
    body = [MARK, ""]
    body.append("### §Perf final table — the three hillclimbed cells "
                "(single pod, 256 chips)\n")
    body.append(perf_table())
    body.append("\n### §Roofline — single-pod baselines (paper-faithful "
                "strategy per arch), all 40 cells\n")
    body.append(table("single"))
    body.append("\n### §Roofline — multi-pod (2×16×16 = 512 chips), "
                "pod-axis proof\n")
    body.append(table("multi"))
    if experiments.exists():
        text = experiments.read_text()
    else:
        text = ("# EXPERIMENTS\n\n"
                "Measured-cell tables regenerated from the dry-run "
                "artifacts by `python -m benchmarks.report` "
                "(see benchmarks/roofline.py).\n\n" + MARK + "\n")
    head = text.split(MARK)[0].rstrip()
    experiments.write_text(head + "\n\n" + "\n".join(body) + "\n")
    print(f"wrote generated tables into {experiments}")


if __name__ == "__main__":
    main()

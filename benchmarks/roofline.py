"""Roofline table (§Roofline): per (arch x shape x mesh) cell, the three
terms derived from the compiled dry-run, the dominant bottleneck, MFU bound,
and the MODEL_FLOPS/HLO_FLOPS useful-compute ratio.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun).
Emits CSV rows for benchmarks.run and a markdown table for EXPERIMENTS.md.

Fabric what-if columns: each measured cell is additionally re-priced under
the named link models in `FABRIC_NAMES` (`repro.core.fabric` presets —
metallic ICI baseline vs photonic interposer designs), showing how the
collective term, bottleneck, and MFU bound move with the network design
point.  The deeper search-driven version — re-ranking the co-design Pareto
frontier by end-to-end step time — lives in `benchmarks.fabric_whatif`.

Also emits the photonic-accelerator roofline (paper Sec. V decomposition):
per (accelerator variant x CNN) the compute / interposer-network / memory
terms and the dominant bottleneck, computed through the batched sweep-engine
accelerator path (core.sweep.evaluate_accelerator_batch).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core import (
    CNN_WORKLOADS,
    crosslight_25d_elec,
    crosslight_25d_siph,
    evaluate_accelerator_batch,
    get_fabric,
    monolithic_crosslight,
)
from repro.launch.hlo_analysis import PEAK_FLOPS

ARTIFACTS = Path(__file__).resolve().parent / "artifacts" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str | None = None, include_tagged: bool = False):
    """Baseline cells are `<arch>__<shape>__<mesh>.json`; hillclimb variants
    carry an extra `__<tag>` suffix and are excluded from the baseline table
    unless `include_tagged` (they land in §Perf instead)."""
    cells = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        parts = f.stem.split("__")
        tag = parts[3] if len(parts) > 3 else ""
        if tag and not include_tagged:
            continue
        r = json.loads(f.read_text())
        r["tag"] = tag
        if mesh and r.get("mesh") != mesh:
            continue
        cells.append(r)
    cells.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                              if r["shape"] in SHAPE_ORDER else 9,
                              r["mesh"], r.get("tag", "")))
    return cells


def mfu_bound(r) -> float:
    """Fraction of chip peak the cell could reach if the step ran at its
    dominant roofline term: useful_time / bound_time."""
    rf = r["roofline"]
    bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    useful = rf["model_flops"] / PEAK_FLOPS
    return useful / bound if bound > 0 else 0.0


def summarize(r) -> dict:
    rf = r["roofline"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "tag": r.get("tag", ""),
        "compute_ms": rf["compute_s"] * 1e3,
        "memory_ms": rf["memory_s"] * 1e3,
        "collective_ms": rf["collective_s"] * 1e3,
        "bottleneck": rf["bottleneck"],
        "mfu_bound": mfu_bound(r),
        "useful_flops_frac": rf["useful_flops_frac"],
        "args_gib": (r["memory"]["argument_size_in_bytes"] or 0) / 2 ** 30,
        "compile_s": r.get("compile_s", 0),
    }


def markdown_table(mesh="single") -> str:
    rows = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
            "bottleneck | MFU bound | useful-FLOPs | args GiB/dev |",
            "|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for r in load_cells(mesh):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"SKIP ({r['skip_reason'][:40]}…) | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — | — |")
            continue
        s = summarize(r)
        rows.append(
            f"| {s['arch']} | {s['shape']} | {s['compute_ms']:.1f} | "
            f"{s['memory_ms']:.1f} | {s['collective_ms']:.1f} | "
            f"**{s['bottleneck']}** | {s['mfu_bound']:.3f} | "
            f"{s['useful_flops_frac']:.2f} | {s['args_gib']:.2f} |")
    return "\n".join(rows)


FABRIC_NAMES = ("metallic_ici", "trine_siph", "tree_siph")


def fabric_terms(r, fabric) -> dict:
    """Re-price one measured dry-run cell under a different fabric: same HLO
    FLOPs/bytes, but the three roofline denominators come from the fabric's
    link model.  `fabric` is anything `core.fabric.get_fabric` accepts."""
    fb = get_fabric(fabric)
    rf = r["roofline"]
    n_coll = float(sum(r.get("collective_op_counts", {}).values()))
    compute_s = rf["flops"] / fb.peak_flops
    memory_s = rf["hbm_bytes"] / fb.hbm_bw_bytes_per_s
    collective_s = fb.collective_s(rf["collective_bytes"], n_coll)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms.values())
    useful = rf["model_flops"] / fb.peak_flops
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "fabric": fb.name,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(terms, key=terms.get),
        "mfu_bound": useful / bound if bound > 0 else 0.0,
    }


def fabric_cells(cells=None, fabrics=FABRIC_NAMES) -> list:
    """Fabric what-if rows for every ok dry-run cell: cell x fabric terms.
    Empty when no dry-run artifacts exist (benchmarks.fabric_whatif covers
    that case with analytic cells)."""
    if cells is None:
        cells = [c for c in load_cells() if c["status"] == "ok"]
    return [fabric_terms(r, f) for r in cells
            if r.get("status", "ok") == "ok" for f in fabrics]


def fabric_markdown_table(rows=None) -> str:
    rows = fabric_cells() if rows is None else rows
    out = ["| arch | shape | fabric | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | MFU bound |",
           "|---|---|---|---:|---:|---:|---|---:|"]
    for s in rows:
        out.append(
            f"| {s['arch']} | {s['shape']} | {s['fabric']} | "
            f"{s['compute_s'] * 1e3:.1f} | {s['memory_s'] * 1e3:.1f} | "
            f"{s['collective_s'] * 1e3:.1f} | **{s['bottleneck']}** | "
            f"{s['mfu_bound']:.3f} |")
    return "\n".join(out)


def photonic_roofline() -> list:
    """Per (accelerator variant x CNN): compute / network / memory seconds
    and the dominant term, via the batched accelerator evaluator."""
    accels = [monolithic_crosslight(), crosslight_25d_elec(),
              crosslight_25d_siph()]
    rows = []
    for name, factory in CNN_WORKLOADS.items():
        wl = factory()
        for a in accels:
            r = evaluate_accelerator_batch(a, wl)
            terms = {"compute": r.compute_s, "network": r.network_s,
                     "memory": r.memory_s}
            rows.append({
                "accel": a.name, "cnn": wl.name,
                "compute_s": r.compute_s, "network_s": r.network_s,
                "memory_s": r.memory_s, "latency_s": r.latency_s,
                "bottleneck": max(terms, key=terms.get),
            })
    return rows


def photonic_markdown_table(photonic=None) -> str:
    rows = ["| accelerator | cnn | compute (ms) | network (ms) | memory (ms) "
            "| bottleneck |",
            "|---|---|---:|---:|---:|---|"]
    for r in (photonic if photonic is not None else photonic_roofline()):
        rows.append(
            f"| {r['accel']} | {r['cnn']} | {r['compute_s'] * 1e3:.3f} | "
            f"{r['network_s'] * 1e3:.3f} | {r['memory_s'] * 1e3:.3f} | "
            f"**{r['bottleneck']}** |")
    return "\n".join(rows)


def run(csv: bool = True) -> dict:
    cells = load_cells()
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    err = [c for c in cells if c["status"] not in ("ok", "skip")]
    photonic = photonic_roofline()
    fabric = fabric_cells(ok)
    out = {"n_ok": len(ok), "n_skip": len(skip), "n_err": len(err),
           "photonic": photonic, "fabric": fabric}
    if csv:
        for s in fabric:
            print(f"roofline/fabric/{s['arch']}/{s['shape']}/{s['fabric']},0,"
                  f"col={s['collective_s'] * 1e3:.1f}ms;"
                  f"bot={s['bottleneck']};mfu_bound={s['mfu_bound']:.3f}")
        for r in photonic:
            print(f"roofline/photonic/{r['accel']}/{r['cnn']},0,"
                  f"cmp={r['compute_s'] * 1e3:.3f}ms;"
                  f"net={r['network_s'] * 1e3:.3f}ms;"
                  f"mem={r['memory_s'] * 1e3:.3f}ms;bot={r['bottleneck']}")
        for r in ok:
            s = summarize(r)
            cell = f"{s['arch']}/{s['shape']}/{s['mesh']}"
            if s["tag"]:
                cell += f"+{s['tag']}"
            print(f"roofline/{cell},"
                  f"{s['compile_s']*1e6:.0f},"
                  f"cmp={s['compute_ms']:.1f}ms;mem={s['memory_ms']:.1f}ms;"
                  f"col={s['collective_ms']:.1f}ms;bot={s['bottleneck']};"
                  f"mfu_bound={s['mfu_bound']:.3f}")
        for r in skip:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,SKIP")
        for r in err:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0,ERROR")
    return out


if __name__ == "__main__":
    _out = run()
    print()
    print(markdown_table("single"))
    if _out["fabric"]:
        print()
        print(fabric_markdown_table(_out["fabric"]))
    print()
    print(photonic_markdown_table(_out["photonic"]))

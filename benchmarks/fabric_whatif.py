"""Fabric what-if: re-rank interposer-network design points by estimated
END-TO-END train/serve step time instead of raw network EDP.

This is the search -> system loop closed: `core.search.codesign_pareto`
finds the network-EDP frontier (Layer A), `core.fabric` converts each
frontier row into a link model, and this benchmark prices every
(arch x shape) roofline cell under every fabric through the SAME
`repro.launch.hlo_analysis.roofline` used for compiled programs — so a
network co-design choice visibly moves a training/serving bottleneck.

Cells are analytic (arch x shape) workload estimates on the production
(2, 16, 16) 512-chip mesh — per-device MODEL_FLOPS (6ND train / 2ND
inference), an HBM traffic model (weights + optimizer state or KV cache),
and collective wire bytes from the same ring-algorithm estimate validated
against compiled HLO in tests/test_distributed.py.  When compiled dry-run
artifacts exist, `benchmarks.roofline.fabric_cells` prices those measured
cells the same way.

Emits artifacts/fabric_whatif.json:
  fabrics   link model of every fabric evaluated (>= 3: metallic baseline,
            photonic presets, deduped co-design frontier points)
  cells     the per-(arch x shape) workload terms (fabric-independent)
  results   one row per cell x fabric: compute/memory/collective seconds,
            step time (max term), bottleneck, MFU bound, collective energy
  ranking   fabrics by geometric-mean step time across cells
  checks    schema/quality gates consumed by benchmarks.run

  PYTHONPATH=src:. python -m benchmarks.fabric_whatif
"""

from __future__ import annotations

import json
import math
import time
from pathlib import Path

import numpy as np

from repro import configs as C
from repro.core import ChipletSpec
from repro.core.fabric import Fabric, fabrics_from_front, get_fabric
from repro.core.search import codesign_pareto
from repro.core.workloads import CNN_WORKLOADS
from repro.env import smoke_mode
from repro.launch import hlo_analysis as H
from repro.parallel.collectives import collective_bytes_estimate

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

# production mesh geometry (pod, data, model) — 512 chips
MESH_SHAPE = (2, 16, 16)


class _MeshLike:
    """Geometry stand-in (avoids forcing 512 devices in the bench process)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


_MESH = _MeshLike(MESH_SHAPE, ("pod", "data", "model"))
_N_DEV = int(np.prod(MESH_SHAPE))

ARCHS_FULL = ("yi_6b", "yi_34b", "deepseek_67b", "grok1_314b")
SHAPES_FULL = ("train_4k", "prefill_32k", "decode_32k")
ARCHS_SMOKE = ("yi_6b", "yi_34b")
SHAPES_SMOKE = ("train_4k", "prefill_32k", "decode_32k")


def _model_flops_per_device(cfg, shape) -> float:
    """6ND (train) / 2ND (inference) per device — mirrors
    repro.launch.dryrun.model_flops_per_device, reimplemented here because
    importing that module forces the 512-device XLA host platform."""
    n = cfg.active_param_count() if cfg.n_experts else cfg.param_count()
    if shape.kind == "train":
        total = 6.0 * n * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.global_batch * shape.seq_len
    else:
        total = 2.0 * n * shape.global_batch
    return total / _N_DEV


def analytic_cell(arch: str, shape_name: str) -> dict:
    """Fabric-independent workload terms of one (arch x shape) cell."""
    cfg = C.get(arch)
    shape = C.SHAPES[shape_name]
    n_params = cfg.param_count()
    flops = _model_flops_per_device(cfg, shape)
    w_bytes = 2.0 * n_params / _N_DEV              # bf16 weights, sharded

    n_pod, n_data, n_model = MESH_SHAPE
    if shape.kind == "train":
        # weights read + grads written (bf16) + Adam m/v read+written (f32)
        hbm = w_bytes * (1 + 1 + 2 * (4 / 2) * 2)
        # per-device gradient sync (bf16, FSDP over pod x data = 256 ranks)
        per_dev = n_params / (n_pod * n_data * n_model) * n_model
        est = collective_bytes_estimate(int(per_dev), 2, _MESH, "trine")
        coll_bytes = est["total_bytes"]
        n_coll = 3                                  # RS / cross-pod / AG
    else:
        b_local = max(1, shape.global_batch // n_data)
        seq = shape.seq_len if shape.kind == "prefill" else 1
        act_elems = b_local * seq * cfg.d_model
        # two TP all-reduces per layer over the model axis (ring factor),
        # plus the sampled-token logits all-reduce over the sharded vocab
        ring = 2.0 * (n_model - 1) / n_model
        coll_bytes = (cfg.n_layers * 2 * ring * act_elems * 2
                      + ring * b_local * cfg.vocab * 2)
        n_coll = cfg.n_layers * 2 + 1
        kv = (shape.global_batch * shape.seq_len * cfg.n_layers
              * 2 * cfg.n_kv_heads * cfg.head_dim_ * 2) / _N_DEV
        hbm = w_bytes + (kv if shape.kind == "decode" else act_elems * 2 * 4)
    return {
        "arch": arch, "shape": shape_name,
        "model_flops_per_device": flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll_bytes,
        "n_collectives": n_coll,
    }


def cell_stats(cell: dict) -> H.HloStats:
    """Wrap a cell's analytic terms as HloStats so the SAME roofline
    function prices measured and analytic cells."""
    return H.HloStats(
        dot_flops=cell["model_flops_per_device"], dot_bytes=0.0,
        op_result_bytes=0.0, collective_bytes=cell["collective_bytes"],
        collective_op_bytes={},
        collective_op_counts={"all-reduce": int(cell["n_collectives"])},
        max_trip=1, collective_bytes_raw=cell["collective_bytes"])


def price_cell(cell: dict, fabric: Fabric) -> dict:
    rf = H.roofline(cell_stats(cell), {}, cell["model_flops_per_device"],
                    io_bytes=cell["hbm_bytes"], fabric=fabric)
    step_s = max(rf.compute_s, rf.memory_s, rf.collective_s)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "fabric": fabric.name,
        "compute_s": rf.compute_s, "memory_s": rf.memory_s,
        "collective_s": rf.collective_s, "step_s": step_s,
        "bottleneck": rf.bottleneck,
        "mfu_bound": (rf.compute_s / step_s) if step_s > 0 else 0.0,
        "collective_energy_j": fabric.collective_energy_j(
            cell["collective_bytes"]),
    }


def frontier_fabrics(smoke: bool):
    """Co-design Pareto frontier -> deduped Fabrics (the what-if inputs).
    The grid deliberately spans slow (tree, few lambda) and fast (trine,
    wide WDM, high mem BW) designs so the frontier brackets the metallic
    baseline from both sides."""
    wl = CNN_WORKLOADS["ResNet18"]()
    mixes = [[ChipletSpec(512, 32)]]
    if smoke:
        axes = dict(n_lambda=(2.0, 8.0), mem_bw_bytes_per_s=(6.25e9, 100e9))
        chunk = 16
    else:
        axes = dict(n_lambda=(2.0, 4.0, 8.0, 16.0),
                    mem_bw_bytes_per_s=(6.25e9, 25e9, 100e9, 200e9),
                    modulation_rate_bps=(8e9, 12e9))
        chunk = 4096
    front, spec = codesign_pareto(wl, mixes, topologies=("tree", "trine"),
                                  chunk_size=chunk, **axes)
    fabs = fabrics_from_front(front, spec, mixes=mixes,
                              max_fabrics=4 if smoke else 8)
    return front, spec, fabs


def _geomean(xs) -> float:
    return float(math.exp(np.mean(np.log(np.maximum(xs, 1e-300)))))


def run(csv: bool = True, smoke: bool | None = None) -> dict:
    smoke = smoke_mode() if smoke is None else smoke
    archs = ARCHS_SMOKE if smoke else ARCHS_FULL
    shapes = SHAPES_SMOKE if smoke else SHAPES_FULL

    t0 = time.perf_counter()
    cells = [analytic_cell(a, s) for a in archs for s in shapes]

    front, spec, pareto_fabs = frontier_fabrics(smoke)
    presets = [get_fabric(n) for n in ("metallic_ici", "trine_siph",
                                       "tree_siph", "elec_mesh")]
    fabrics = presets + pareto_fabs

    results = [price_cell(c, f) for c in cells for f in fabrics]
    by_fab = {f.name: [r for r in results if r["fabric"] == f.name]
              for f in fabrics}
    ranking = sorted(
        ({"fabric": name, "geomean_step_s": _geomean([r["step_s"]
                                                      for r in rows])}
         for name, rows in by_fab.items()),
        key=lambda r: r["geomean_step_s"])
    frontier_ranking = [r["fabric"] for r in ranking
                        if r["fabric"].startswith("pareto:")]

    base = {(r["arch"], r["shape"]): r for r in by_fab["metallic_ici"]}

    def flips(rows):
        """(arch, shape, fabric, metallic bottleneck -> this bottleneck)."""
        return [
            (r["arch"], r["shape"], r["fabric"],
             base[(r["arch"], r["shape"])]["bottleneck"], r["bottleneck"])
            for r in rows
            if r["bottleneck"] != base[(r["arch"], r["shape"])]["bottleneck"]]

    preset_flips = [fl for f in presets[1:] for fl in flips(by_fab[f.name])]
    frontier_flips = [fl for f in pareto_fabs for fl in flips(by_fab[f.name])]

    # monotonicity spot check: trine_siph's cross-pod link is ~2x metallic's,
    # so its collective term must be strictly smaller on every cell
    trine = {(r["arch"], r["shape"]): r for r in by_fab["trine_siph"]}
    mono = all(trine[k]["collective_s"] < base[k]["collective_s"]
               for k in base)

    frontier_idx = {int(f.name.rsplit("@", 1)[1]) for f in pareto_fabs}
    subset = frontier_idx <= {int(i) for i in front.indices}

    checks = {
        "n_fabrics_ge_3": len(fabrics) >= 3,
        "has_frontier_fabric": len(pareto_fabs) >= 1,
        "bottleneck_flip_vs_metallic": len(preset_flips) + len(
            frontier_flips) >= 1,
        "bottleneck_flip_frontier_fabric": len(frontier_flips) >= 1,
        "collective_s_monotone_in_bw": mono,
        "ranked_frontier_subset_of_edp_front": subset,
        "all_terms_finite": all(
            np.isfinite([r["compute_s"], r["memory_s"], r["collective_s"]]
                        ).all() for r in results),
    }
    elapsed = time.perf_counter() - t0

    out = {
        "smoke": smoke,
        "mesh_shape": list(MESH_SHAPE),
        "fabrics": [{
            "name": f.name,
            "kind": "frontier" if f.name.startswith("pareto:") else "preset",
            "cross_pod_bw_bytes_per_s": f.cross_pod_bw_bytes_per_s,
            "intra_pod_bw_bytes_per_s": f.intra_pod_bw_bytes_per_s,
            "link_latency_s": f.link_latency_s,
            "energy_per_bit_j": f.energy_per_bit_j,
            "source": f.source,
        } for f in fabrics],
        "cells": cells,
        "results": results,
        "ranking": ranking,
        "frontier_ranking": frontier_ranking,
        "edp_front_size": front.size,
        "checks": checks,
        "required_checks": list(checks),
        "pass": all(checks.values()),
        "elapsed_s": elapsed,
    }
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "fabric_whatif.json").write_text(json.dumps(out, indent=1))

    if csv:
        us = elapsed * 1e6 / max(1, len(results))
        for r in ranking:
            print(f"fabric_whatif/rank/{r['fabric']},{us:.1f},"
                  f"geomean_step={r['geomean_step_s'] * 1e3:.3f}ms")
        for a, s, fab, old, new in (preset_flips + frontier_flips)[:8]:
            print(f"fabric_whatif/flip/{a}/{s}/{fab},0,{old}->{new}")
        print(f"fabric_whatif/pass,0,"
              f"{'PASS' if out['pass'] else 'FAIL'}")
    return out


def markdown_table(out: dict | None = None) -> str:
    """Per-cell summary: step time + bottleneck under each fabric."""
    out = out or run(csv=False)
    fabs = [f["name"] for f in out["fabrics"]]
    by = {(r["arch"], r["shape"], r["fabric"]): r for r in out["results"]}
    rows = ["| arch | shape | " + " | ".join(fabs) + " |",
            "|---|---|" + "---|" * len(fabs)]
    for c in out["cells"]:
        vals = []
        for f in fabs:
            r = by[(c["arch"], c["shape"], f)]
            vals.append(f"{r['step_s'] * 1e3:.2f}ms ({r['bottleneck'][:4]})")
        rows.append(f"| {c['arch']} | {c['shape']} | " + " | ".join(vals)
                    + " |")
    return "\n".join(rows)


if __name__ == "__main__":
    _out = run()
    print()
    print(markdown_table(_out))

"""Paper Fig. 6: CrossLight (monolithic) vs 2.5D-CrossLight-Elec-Interposer vs
2.5D-CrossLight-SiPh-Interposer — normalized power, latency, energy-per-bit
over six CNNs, plus the paper's headline average ratios:

  SiPh vs monolithic : 6.6x lower latency, 2.8x lower EPB
  SiPh vs electrical : 34x lower latency, 15.8x lower EPB
  LeNet5             : the stated exception (too small to use the platform)
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (
    CNN_WORKLOADS,
    crosslight_25d_elec,
    crosslight_25d_siph,
    evaluate_accelerator_batch,
    monolithic_crosslight,
)

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

PAPER_CLAIMS = {
    "mono_over_siph_latency": 6.6,
    "mono_over_siph_epb": 2.8,
    "elec_over_siph_latency": 34.0,
    "elec_over_siph_epb": 15.8,
}


def run(csv: bool = True) -> dict:
    accels = [monolithic_crosslight(), crosslight_25d_elec(), crosslight_25d_siph()]
    rows = []
    t0 = time.perf_counter()
    for name, factory in CNN_WORKLOADS.items():
        wl = factory()
        # batched path: per-layer loop replaced by one struct-of-arrays
        # evaluation per (accelerator, workload) — see core.sweep
        reps = {a.name: evaluate_accelerator_batch(a, wl) for a in accels}
        m = reps["CrossLight"]
        e = reps["2.5D-CrossLight-Elec"]
        s = reps["2.5D-CrossLight-SiPh"]
        rows.append(
            {
                "cnn": wl.name,
                "latency_s": {k: r.latency_s for k, r in reps.items()},
                "power_w": {k: r.power_w for k, r in reps.items()},
                "epb_pj": {k: r.epb_j * 1e12 for k, r in reps.items()},
                "mono_over_siph_latency": m.latency_s / s.latency_s,
                "mono_over_siph_epb": m.epb_j / s.epb_j,
                "elec_over_siph_latency": e.latency_s / s.latency_s,
                "elec_over_siph_epb": e.epb_j / s.epb_j,
            }
        )
    us = (time.perf_counter() - t0) * 1e6 / max(1, len(rows))

    avg = {
        k: float(np.mean([r[k] for r in rows]))
        for k in PAPER_CLAIMS
    }
    # paper: averages include all six CNNs (LeNet5 drags the mean down; the
    # paper calls it out as the exception where the 2.5D platform is
    # inefficiently utilized)
    checks = {
        # within a factor-2 band of the paper's reported averages — the paper
        # used a cycle-accurate in-house simulator; ours is analytical
        k: (avg[k] >= PAPER_CLAIMS[k] / 2.0) and (avg[k] <= PAPER_CLAIMS[k] * 2.0)
        for k in PAPER_CLAIMS
    }
    lenet = next(r for r in rows if r["cnn"] == "LeNet5")
    checks["lenet5_monolithic_competitive"] = lenet["mono_over_siph_epb"] < 1.5

    out = {"rows": rows, "avg": avg, "paper": PAPER_CLAIMS, "checks": checks}
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "fig6_crosslight.json").write_text(json.dumps(out, indent=2))

    if csv:
        for r in rows:
            print(
                f"fig6/{r['cnn']},{us:.1f},"
                f"m/s_L={r['mono_over_siph_latency']:.2f};m/s_EPB={r['mono_over_siph_epb']:.2f};"
                f"e/s_L={r['elec_over_siph_latency']:.2f};e/s_EPB={r['elec_over_siph_epb']:.2f}"
            )
        for k in PAPER_CLAIMS:
            print(f"fig6/avg/{k},{us:.1f},{avg[k]:.2f} (paper {PAPER_CLAIMS[k]})")
        for k, v in checks.items():
            print(f"fig6/check/{k},{us:.1f},{'PASS' if v else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()

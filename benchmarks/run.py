"""Benchmark harness entry point — one function per paper table/figure plus
the roofline report.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src:. python -m benchmarks.run
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

jax.config.update("jax_enable_x64", True)

from benchmarks import fig4_trine          # paper Fig. 4
from benchmarks import fig6_crosslight     # paper Fig. 6
from benchmarks import sweep_bench         # batched vs scalar sweep engine
from benchmarks import collectives_bench   # Layer-B collective schedules
from benchmarks import roofline            # §Roofline report
from benchmarks import photonic_mac_bench  # kernel microbench


def main() -> None:
    print("# fig4: TRINE vs SPACX/SPRINT/Tree (paper Fig. 4)")
    fig4_trine.run()
    print("# fig6: CrossLight vs 2.5D-Elec vs 2.5D-SiPh (paper Fig. 6)")
    fig6_crosslight.run()
    print("# sweep engine: batched vs scalar design-space throughput")
    sweep_bench.run()
    print("# collective schedules: flat vs TRINE-hierarchical vs +int8")
    collectives_bench.run()
    print("# photonic-MAC kernel microbenchmark")
    photonic_mac_bench.run()
    print("# roofline (from dry-run artifacts)")
    roofline.run()


if __name__ == "__main__":
    main()

"""Benchmark harness entry point — one function per paper table/figure plus
the roofline report.  Prints ``name,us_per_call,derived`` CSV and writes a
consolidated ``artifacts/summary.json`` with every benchmark's checks and
the cross-benchmark perf-regression gates (batched >= 20x scalar, chunked
within 1.5x of monolithic, device-pipelined streaming >= 1.2x host-serial on
the full-mode grid — smoke runs use each benchmark's recorded smoke bar).
Also writes ``artifacts/BENCH_9.json``, the perf-trajectory artifact for the
streaming engine (configs/sec by path, overlap gains, grid sizes).

  PYTHONPATH=src:. python -m benchmarks.run
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

from benchmarks import fig4_trine          # paper Fig. 4
from benchmarks import fig6_crosslight     # paper Fig. 6
from benchmarks import sweep_bench         # batched vs scalar sweep engine
from benchmarks import pareto_bench        # Pareto/co-design search engine
from benchmarks import collectives_bench   # Layer-B collective schedules
from benchmarks import roofline            # §Roofline report
from benchmarks import fabric_whatif       # frontier fabrics -> step time
from benchmarks import resilience_bench    # fault model / survivability
from benchmarks import photonic_mac_bench  # kernel microbench
from tools import lint                     # static-analysis gate

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

# artifacts/fabric_whatif.json contract consumed by downstream reports
FABRIC_WHATIF_SCHEMA = {
    "fabrics": list, "cells": list, "results": list, "ranking": list,
    "frontier_ranking": list, "checks": dict, "pass": bool,
}
_FABRIC_RESULT_KEYS = ("arch", "shape", "fabric", "compute_s", "memory_s",
                       "collective_s", "step_s", "bottleneck")


def check_fabric_whatif_schema(res: dict) -> dict:
    """Schema gate for the fabric what-if artifact: top-level keys typed per
    FABRIC_WHATIF_SCHEMA, every result row carrying the roofline terms, and
    >= 3 fabrics including a co-design frontier point."""
    shape_ok = all(isinstance(res.get(k), t)
                   for k, t in FABRIC_WHATIF_SCHEMA.items())
    rows_ok = shape_ok and all(
        all(k in r for k in _FABRIC_RESULT_KEYS) for r in res["results"])
    return {
        "schema_keys": shape_ok,
        "schema_result_rows": rows_ok,
        "schema_fabric_count": shape_ok and len(res["fabrics"]) >= 3,
        "schema_has_frontier": shape_ok and any(
            f.get("kind") == "frontier" for f in res["fabrics"]),
    }


def build_summary(results: dict) -> dict:
    """Consolidate per-benchmark result dicts: flatten their checks and
    evaluate the perf-regression gates.

    Gates (each benchmark records the bar it actually ran against, so smoke
    runs gate on the smoke bar and full runs on the full bar):
      * sweep_bench:  batched configs/sec >= bar x scalar
      * pareto_bench: chunked evaluation within bar x of monolithic (both
        the network grid and the co-design grid), fronts exactly equal
        between streaming and monolithic paths, the refined co-design
        front weakly dominating its seed front, the trust-region
        multi-workload front weakly dominating the first-order front, and
        every trust-region design re-scoring bit-identically (all required
        in both modes); the strict "refined_improves_a_seed" gate is
        required in full mode and honestly exempted (computed + flagged,
        never rewritten) in smoke via each benchmark's `required_checks`
        list.
      * lint: byte-compilation and import hygiene over src/benchmarks/
        examples/tools (tools/lint.py) — required in both modes.

    Also records a "refinement" block: best improvement / fronts moved by
    the first-order and trust-region engines, for perf-trajectory reads.
    """
    checks = {}
    for name, res in results.items():
        for k, v in (res.get("checks") or {}).items():
            required = res.get("required_checks")
            if required is not None and k not in required:
                continue
            checks[f"{name}/{k}"] = bool(v)

    # fabric what-if gates: artifact schema + the bottleneck-flip contract
    # (its own checks dict — folded above — already requires a flip between
    # metallic_ici and a frontier photonic fabric)
    fw = results.get("fabric_whatif")
    if fw:
        for k, v in check_fabric_whatif_schema(fw).items():
            checks[f"fabric_whatif/{k}"] = bool(v)

    perf = {}
    sweep_res = results.get("sweep")
    if sweep_res:
        perf["batched_over_scalar"] = {
            "value": sweep_res["speedup"],
            "bar": sweep_res["speedup_bar"],
            "pass": sweep_res["speedup"] >= sweep_res["speedup_bar"],
        }
    pareto_res = results.get("pareto")
    if pareto_res:
        bar = pareto_res["ratio_bar"]
        for section in ("network", "codesign"):
            ratio = pareto_res[section]["chunked_over_monolithic"]
            perf[f"chunked_over_monolithic_{section}"] = {
                "value": ratio, "bar": bar, "pass": ratio <= bar}
        # device-pipelined streaming vs host-serial materialization: gated
        # only on the full-mode (>= 1e6 point) grid — the smoke grid cannot
        # amortize per-chunk dispatch, and pareto_bench already records the
        # smoke value via its exempted required_checks entry
        pipe = pareto_res.get("pipeline")
        if pipe and not pareto_res["smoke"]:
            perf["pipelined_over_serial"] = {
                "value": pipe["pipelined_over_host_serial"],
                "bar": pipe["speedup_bar"],
                "pass": (pipe["pipelined_over_host_serial"]
                         >= pipe["speedup_bar"]),
            }

    # refinement record: how far each descent engine moved the co-design
    # frontier (pareto_bench gates the dominance + bit-identity contracts;
    # this block is the summary-level trajectory a regression hunt reads)
    refinement = None
    if pareto_res:
        fo = pareto_res.get("refined_front") or {}
        tr = pareto_res.get("trust_region_front") or {}
        refinement = {
            "first_order": {
                "best_improvement": fo.get("best_improvement"),
                "n_improved": fo.get("n_improved"),
                "merged_front_size": fo.get("merged_front_size"),
            },
            "trust_region": {
                "best_improvement": tr.get("best_improvement"),
                "n_improved": tr.get("n_improved"),
                "front_size": tr.get("trust_region_front_size"),
                "workloads": tr.get("workloads"),
                "line_search": tr.get("line_search"),
            },
            "trust_region_dominates_first_order": bool(
                (pareto_res.get("checks") or {}).get(
                    "trust_region_front_dominates_first_order")),
        }

    ok = all(checks.values()) and all(p["pass"] for p in perf.values())
    return {"checks": checks, "perf": perf, "refinement": refinement,
            "pass": ok, "benchmarks": results}


def write_summary(results: dict) -> dict:
    summary = build_summary(results)
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "summary.json").write_text(json.dumps(summary, indent=2))
    return summary


def build_bench9(results: dict) -> dict:
    """Perf-trajectory artifact for the streaming-engine work (BENCH_9):
    the throughput numbers a future regression hunt needs in one place —
    batched vs scalar configs/sec, chunked-vs-monolithic ratios, and the
    pipeline overlap figures, each tagged with the grid it ran on."""
    sweep_res = results.get("sweep") or {}
    pareto_res = results.get("pareto") or {}
    pipe = pareto_res.get("pipeline") or {}
    return {
        "bench": "device_resident_streaming_pipeline",
        "smoke": bool(pareto_res.get("smoke", sweep_res.get("smoke", True))),
        "batched_configs_per_s": sweep_res.get("batched_configs_per_s"),
        "scalar_configs_per_s": sweep_res.get("scalar_configs_per_s"),
        "batched_over_scalar": sweep_res.get("speedup"),
        "pipelined_configs_per_s": sweep_res.get("pipelined_configs_per_s"),
        "chunked_over_monolithic": {
            s: (pareto_res.get(s) or {}).get("chunked_over_monolithic")
            for s in ("network", "codesign")},
        "pipeline": pipe,
        "pipelined_over_host_serial": pipe.get("pipelined_over_host_serial"),
        "overlap_gain_over_device_serial":
            pipe.get("overlap_gain_over_device_serial"),
        "grid_sizes": {
            "sweep": sweep_res.get("n_configs"),
            "network": (pareto_res.get("network") or {}).get("n_configs"),
            "pipeline": pipe.get("n_configs"),
            "codesign_joint":
                (pareto_res.get("codesign") or {}).get("n_joint_points"),
        },
    }


def write_bench9(results: dict) -> dict:
    bench = build_bench9(results)
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "BENCH_9.json").write_text(json.dumps(bench, indent=2))
    return bench


def main() -> None:
    # set here, not at import: the smoke tests import this module in-process
    # and a module-scope flip would leak float64 into the whole test run
    jax.config.update("jax_enable_x64", True)
    results = {}
    print("# fig4: TRINE vs SPACX/SPRINT/Tree (paper Fig. 4)")
    results["fig4"] = fig4_trine.run()
    print("# fig6: CrossLight vs 2.5D-Elec vs 2.5D-SiPh (paper Fig. 6)")
    results["fig6"] = fig6_crosslight.run()
    print("# sweep engine: batched vs scalar design-space throughput")
    results["sweep"] = sweep_bench.run()
    print("# pareto/co-design search: chunked vs monolithic vs scalar")
    results["pareto"] = pareto_bench.run()
    print("# collective schedules: flat vs TRINE-hierarchical vs +int8")
    results["collectives"] = collectives_bench.run()
    print("# photonic-MAC kernel microbenchmark")
    results["photonic_mac"] = photonic_mac_bench.run()
    print("# roofline (from dry-run artifacts)")
    results["roofline"] = roofline.run()
    print("# fabric what-if: frontier fabrics vs end-to-end step time")
    results["fabric_whatif"] = fabric_whatif.run()
    print("# resilience: fault degradation curves + Monte-Carlo availability")
    results["resilience"] = resilience_bench.run()
    print("# static-analysis gate (tools/lint.py)")
    lint_res = lint.run()
    results["lint"] = {
        "engine": lint_res["engine"],
        "n_files": lint_res["n_files"],
        "n_findings": len(lint_res["findings"]),
        "findings": lint_res["findings"][:50],
        "checks": {
            "compile_ok": lint_res["compile_ok"],
            "no_lint_findings": not lint_res["findings"],
        },
    }
    print(f"lint/static_analysis,0,engine={lint_res['engine']} "
          f"files={lint_res['n_files']} "
          f"findings={len(lint_res['findings'])} "
          f"{'PASS' if lint_res['ok'] else 'FAIL'}")

    summary = write_summary(results)
    bench9 = write_bench9(results)
    print("# perf trajectory -> artifacts/BENCH_9.json")
    if bench9["pipelined_over_host_serial"] is not None:
        print(f"bench9/pipelined_over_host_serial,0,"
              f"{bench9['pipelined_over_host_serial']:.2f}x on "
              f"{bench9['grid_sizes']['pipeline']} rows")
    print("# consolidated summary -> artifacts/summary.json")
    for k, p in summary["perf"].items():
        print(f"summary/perf/{k},0,{p['value']:.2f} vs bar {p['bar']} "
              f"{'PASS' if p['pass'] else 'FAIL'}")
    print(f"summary/pass,0,{'PASS' if summary['pass'] else 'FAIL'}")


if __name__ == "__main__":
    main()

"""Benchmark harness entry point — one function per paper table/figure plus
the roofline report.  Prints ``name,us_per_call,derived`` CSV and writes a
consolidated ``artifacts/summary.json`` with every benchmark's checks and
the cross-benchmark perf-regression gates (batched >= 20x scalar, chunked
within 1.5x of monolithic — smoke runs use each benchmark's recorded smoke
bar).

  PYTHONPATH=src:. python -m benchmarks.run
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

from benchmarks import fig4_trine          # paper Fig. 4
from benchmarks import fig6_crosslight     # paper Fig. 6
from benchmarks import sweep_bench         # batched vs scalar sweep engine
from benchmarks import pareto_bench        # Pareto/co-design search engine
from benchmarks import collectives_bench   # Layer-B collective schedules
from benchmarks import roofline            # §Roofline report
from benchmarks import fabric_whatif       # frontier fabrics -> step time
from benchmarks import resilience_bench    # fault model / survivability
from benchmarks import photonic_mac_bench  # kernel microbench

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

# artifacts/fabric_whatif.json contract consumed by downstream reports
FABRIC_WHATIF_SCHEMA = {
    "fabrics": list, "cells": list, "results": list, "ranking": list,
    "frontier_ranking": list, "checks": dict, "pass": bool,
}
_FABRIC_RESULT_KEYS = ("arch", "shape", "fabric", "compute_s", "memory_s",
                       "collective_s", "step_s", "bottleneck")


def check_fabric_whatif_schema(res: dict) -> dict:
    """Schema gate for the fabric what-if artifact: top-level keys typed per
    FABRIC_WHATIF_SCHEMA, every result row carrying the roofline terms, and
    >= 3 fabrics including a co-design frontier point."""
    shape_ok = all(isinstance(res.get(k), t)
                   for k, t in FABRIC_WHATIF_SCHEMA.items())
    rows_ok = shape_ok and all(
        all(k in r for k in _FABRIC_RESULT_KEYS) for r in res["results"])
    return {
        "schema_keys": shape_ok,
        "schema_result_rows": rows_ok,
        "schema_fabric_count": shape_ok and len(res["fabrics"]) >= 3,
        "schema_has_frontier": shape_ok and any(
            f.get("kind") == "frontier" for f in res["fabrics"]),
    }


def build_summary(results: dict) -> dict:
    """Consolidate per-benchmark result dicts: flatten their checks and
    evaluate the perf-regression gates.

    Gates (each benchmark records the bar it actually ran against, so smoke
    runs gate on the smoke bar and full runs on the full bar):
      * sweep_bench:  batched configs/sec >= bar x scalar
      * pareto_bench: chunked evaluation within bar x of monolithic (both
        the network grid and the co-design grid), fronts exactly equal
        between streaming and monolithic paths, and the refined co-design
        front weakly dominating its seed front (required in both modes);
        the strict "refined_improves_a_seed" gate is required in full mode
        and honestly exempted (computed + flagged, never rewritten) in
        smoke via each benchmark's `required_checks` list.
    """
    checks = {}
    for name, res in results.items():
        for k, v in (res.get("checks") or {}).items():
            required = res.get("required_checks")
            if required is not None and k not in required:
                continue
            checks[f"{name}/{k}"] = bool(v)

    # fabric what-if gates: artifact schema + the bottleneck-flip contract
    # (its own checks dict — folded above — already requires a flip between
    # metallic_ici and a frontier photonic fabric)
    fw = results.get("fabric_whatif")
    if fw:
        for k, v in check_fabric_whatif_schema(fw).items():
            checks[f"fabric_whatif/{k}"] = bool(v)

    perf = {}
    sweep_res = results.get("sweep")
    if sweep_res:
        perf["batched_over_scalar"] = {
            "value": sweep_res["speedup"],
            "bar": sweep_res["speedup_bar"],
            "pass": sweep_res["speedup"] >= sweep_res["speedup_bar"],
        }
    pareto_res = results.get("pareto")
    if pareto_res:
        bar = pareto_res["ratio_bar"]
        for section in ("network", "codesign"):
            ratio = pareto_res[section]["chunked_over_monolithic"]
            perf[f"chunked_over_monolithic_{section}"] = {
                "value": ratio, "bar": bar, "pass": ratio <= bar}

    ok = all(checks.values()) and all(p["pass"] for p in perf.values())
    return {"checks": checks, "perf": perf, "pass": ok,
            "benchmarks": results}


def write_summary(results: dict) -> dict:
    summary = build_summary(results)
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "summary.json").write_text(json.dumps(summary, indent=2))
    return summary


def main() -> None:
    # set here, not at import: the smoke tests import this module in-process
    # and a module-scope flip would leak float64 into the whole test run
    jax.config.update("jax_enable_x64", True)
    results = {}
    print("# fig4: TRINE vs SPACX/SPRINT/Tree (paper Fig. 4)")
    results["fig4"] = fig4_trine.run()
    print("# fig6: CrossLight vs 2.5D-Elec vs 2.5D-SiPh (paper Fig. 6)")
    results["fig6"] = fig6_crosslight.run()
    print("# sweep engine: batched vs scalar design-space throughput")
    results["sweep"] = sweep_bench.run()
    print("# pareto/co-design search: chunked vs monolithic vs scalar")
    results["pareto"] = pareto_bench.run()
    print("# collective schedules: flat vs TRINE-hierarchical vs +int8")
    results["collectives"] = collectives_bench.run()
    print("# photonic-MAC kernel microbenchmark")
    results["photonic_mac"] = photonic_mac_bench.run()
    print("# roofline (from dry-run artifacts)")
    results["roofline"] = roofline.run()
    print("# fabric what-if: frontier fabrics vs end-to-end step time")
    results["fabric_whatif"] = fabric_whatif.run()
    print("# resilience: fault degradation curves + Monte-Carlo availability")
    results["resilience"] = resilience_bench.run()

    summary = write_summary(results)
    print("# consolidated summary -> artifacts/summary.json")
    for k, p in summary["perf"].items():
        print(f"summary/perf/{k},0,{p['value']:.2f} vs bar {p['bar']} "
              f"{'PASS' if p['pass'] else 'FAIL'}")
    print(f"summary/pass,0,{'PASS' if summary['pass'] else 'FAIL'}")


if __name__ == "__main__":
    main()

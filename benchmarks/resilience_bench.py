"""Survivability benchmark: how gracefully does each interposer network
degrade as photonic faults accumulate — and does replanning recover what a
naive (healthy-plan) schedule loses?

Three views, all through `core.faults`:

  degradation   per-topology curves of latency / EDP / EPB vs. fault
                severity (deterministic expected scenarios from a scaled
                FaultModel).  Invariant: latency and EDP are monotone
                non-improving in severity for every topology.
  recovery      the TRINE preset fabric degraded at each severity, priced
                through the overlapped-step model with (a) the healthy
                channel plan and (b) a replanned channel count.  Invariant:
                replanned step time <= naive step time everywhere.
  redundancy    Monte-Carlo availability under laser-bank / gateway
                failures (common random draws across topologies): TRINE's K
                subnetwork banks lose K-th fractions where Tree's single
                bank dies outright and SPACX's fewer cluster banks lose
                larger fractions.  Availability is P(degraded EPB <= 2x the
                design's own healthy EPB) — "equal healthy EDP" budgets.
  yield grid    the chunked Monte-Carlo availability column over a
                >= 1e5-point design grid (even in smoke: chunking bounds
                memory, not grid size), plus a healthy reference pass
                asserting expected degraded EDP >= healthy EDP pointwise.

Emits artifacts/resilience.json; checks consumed by benchmarks.run.

  PYTHONPATH=src:. python -m benchmarks.resilience_bench
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (
    FaultModel,
    HEALTHY,
    Traffic,
    availability_search,
    degrade,
    evaluate_degraded,
    get_fabric,
    overlapped_step_s,
    plan_collective_channels,
)
from repro.core.workloads import CNN_WORKLOADS
from repro.env import prefetch_depth, smoke_mode

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

TOPOLOGIES = ("trine", "tree", "spacx", "sprint", "elec")

# baseline fault rates at severity 1.0 (scaled along the curve axis)
BASE_MODEL = FaultModel(p_lambda=0.15, p_bank=0.12, p_gateway=0.05,
                        wpe_loss=0.2, drift_sigma_db=0.5, tuning_sigma=0.3)

# bank/gateway-dominated model for the redundancy Monte-Carlo: large enough
# bank-failure rate that multi-bank redundancy separates from single-bank
MC_MODEL = FaultModel(p_bank=0.15, p_gateway=0.02, p_lambda=0.05)

SEVERITIES_FULL = (0.0, 0.25, 0.5, 1.0, 1.5, 2.0)
SEVERITIES_SMOKE = (0.0, 0.5, 2.0)

# gradient-collective sizing for the recovery view (~0.5B-param DP step)
RECOVERY_BYTES = 2.0 * 2**30
RECOVERY_WINDOW_S = 50e-3


def degradation_curves(traffic: Traffic, severities) -> list:
    rows = []
    for topo in TOPOLOGIES:
        for s in severities:
            scenario = BASE_MODEL.scale(s).expected(name=f"sev{s:g}")
            m = evaluate_degraded(traffic, scenario, topo)
            lat = float(m["latency_s"][0])
            en = float(m["energy_j"][0])
            rows.append({
                "topology": topo, "severity": float(s),
                "latency_s": lat, "energy_j": en, "edp": lat * en,
                "energy_per_bit_j": float(m["energy_per_bit_j"][0]),
            })
    return rows


def check_monotone(rows) -> bool:
    """Latency and EDP non-decreasing along each topology's severity curve.
    (power_w is intentionally excluded: a dead network has no dynamic
    power, so raw power is not monotone in severity.)"""
    ok = True
    for topo in TOPOLOGIES:
        curve = sorted((r for r in rows if r["topology"] == topo),
                       key=lambda r: r["severity"])
        for a, b in zip(curve, curve[1:]):
            ok &= b["latency_s"] >= a["latency_s"] * (1 - 1e-9)
            ok &= b["edp"] >= a["edp"] * (1 - 1e-9)
    return bool(ok)


def recovery_rows(severities) -> list:
    """Degraded-fabric step time with the healthy channel plan vs. a
    replanned channel count, per severity."""
    fb = get_fabric("trine_siph")
    ch_healthy = plan_collective_channels(
        RECOVERY_BYTES, RECOVERY_WINDOW_S, fabric=fb, max_channels=64)
    rows = []
    for s in severities:
        scenario = BASE_MODEL.scale(s).expected(name=f"sev{s:g}")
        fbd = degrade(fb, scenario)
        naive = overlapped_step_s(RECOVERY_WINDOW_S, RECOVERY_BYTES,
                                  fbd, ch_healthy)
        ch_re = plan_collective_channels(
            RECOVERY_BYTES, RECOVERY_WINDOW_S, fabric=fbd, max_channels=64)
        replanned = overlapped_step_s(RECOVERY_WINDOW_S, RECOVERY_BYTES,
                                      fbd, ch_re)
        rows.append({
            "severity": float(s), "fabric": fbd.name,
            "cross_pod_gbps": fbd.cross_pod_bw_bytes_per_s / 1e9,
            "channels_naive": int(ch_healthy), "channels_replanned": int(ch_re),
            "step_s_naive": float(naive), "step_s_replanned": float(replanned),
        })
    return rows


def redundancy_availability(traffic: Traffic, n_draws: int) -> dict:
    """Common-random-draw Monte-Carlo availability per topology: a design is
    available when its degraded EPB stays within 2x its OWN healthy EPB
    (budgets normalized per design — "equal healthy EDP")."""
    scenarios = MC_MODEL.sample(n_draws, rng=7)
    out = {}
    for topo in ("trine", "tree", "spacx"):
        healthy_epb = float(
            evaluate_degraded(traffic, HEALTHY, topo)["energy_per_bit_j"][0])
        epb = evaluate_degraded(traffic, scenarios, topo)["energy_per_bit_j"]
        out[topo] = float(np.mean(epb <= 2.0 * healthy_epb))
    return out


def yield_grid(traffic: Traffic, n_draws: int, chunk_size: int) -> dict:
    """Chunked Monte-Carlo availability columns over a >= 1e5-point grid,
    plus a healthy single-scenario pass for the pointwise EDP comparison."""
    axes = {
        "n_lambda": (2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0),
        "modulation_rate_bps": tuple(np.linspace(6e9, 20e9, 8)),
        "mem_bw_bytes_per_s": tuple(np.linspace(50e9, 400e9, 8)),
        "mzi.insertion_loss_db": (0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75,
                                  2.0),
        "interposer_side_cm": (2.0, 3.0, 4.0, 6.0, 8.0),
    }
    scenarios = BASE_MODEL.sample(n_draws, rng=11)
    healthy = evaluate_degraded(traffic, HEALTHY, "trine")  # budget anchor
    budget = 2.0 * float(healthy["energy_per_bit_j"][0])
    # device-materialized, prefetch-pipelined streaming (the engine default,
    # pinned + recorded here so the artifact states what was measured; any
    # (materialize, prefetch) combination is bit-identical by contract)
    depth = prefetch_depth()
    t0 = time.perf_counter()
    mc = availability_search(traffic, scenarios, topologies=TOPOLOGIES,
                             epb_budget_j=budget, chunk_size=chunk_size,
                             materialize="device", prefetch=depth, **axes)
    mc_s = time.perf_counter() - t0
    ref = availability_search(traffic, HEALTHY, topologies=TOPOLOGIES,
                              epb_budget_j=budget, chunk_size=chunk_size,
                              materialize="device", prefetch=depth, **axes)
    return {
        "n_points": int(mc["n"]),
        "n_scenarios": int(mc["n_scenarios"]),
        "chunk_size": int(chunk_size),
        "materialize": "device",
        "prefetch_depth": int(depth),
        "epb_budget_j": budget,
        "mc_seconds": mc_s,
        "availability_min": float(np.min(mc["availability"])),
        "availability_max": float(np.max(mc["availability"])),
        "availability_mean": float(np.mean(mc["availability"])),
        "best_survivable": mc["best_survivable"],
        "edp_ge_healthy": bool(np.all(
            mc["expected_edp"] >= ref["expected_edp"] * (1 - 1e-9))),
    }


def run(csv: bool = True, smoke: bool | None = None) -> dict:
    smoke = smoke_mode() if smoke is None else smoke
    severities = SEVERITIES_SMOKE if smoke else SEVERITIES_FULL
    n_draws_mc = 64 if smoke else 256
    n_draws_grid = 4 if smoke else 16
    chunk_size = 8192

    traffic = CNN_WORKLOADS["ResNet18"]().traffic()

    t0 = time.perf_counter()
    curves = degradation_curves(traffic, severities)
    recovery = recovery_rows(severities)
    avail = redundancy_availability(traffic, n_draws_mc)
    grid = yield_grid(traffic, n_draws_grid, chunk_size)
    wall_s = time.perf_counter() - t0

    checks = {
        "monotone_degradation": check_monotone(curves),
        "replan_recovers": all(
            r["step_s_replanned"] <= r["step_s_naive"] * (1 + 1e-9)
            for r in recovery),
        "trine_redundancy_beats_tree": avail["trine"] > avail["tree"],
        "trine_redundancy_at_least_spacx": avail["trine"] >= avail["spacx"],
        "availability_grid_at_least_1e5": grid["n_points"] >= 100_000,
        "availability_in_unit_interval": (
            0.0 <= grid["availability_min"]
            and grid["availability_max"] <= 1.0),
        "expected_edp_ge_healthy": grid["edp_ge_healthy"],
    }
    out = {
        "smoke": bool(smoke),
        "wall_s": wall_s,
        "degradation": curves,
        "recovery": recovery,
        "availability": avail,
        "yield_grid": grid,
        "checks": checks,
        "required_checks": list(checks),
        "pass": all(checks.values()),
    }
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "resilience.json").write_text(json.dumps(out, indent=1))
    if csv:
        for r in curves:
            print(f"resilience/degradation/{r['topology']}/"
                  f"sev{r['severity']:g},0,edp={r['edp']:.3e}")
        for r in recovery:
            print(f"resilience/recovery/sev{r['severity']:g},0,"
                  f"naive={r['step_s_naive']:.4f}s "
                  f"replanned={r['step_s_replanned']:.4f}s "
                  f"ch={r['channels_naive']}->{r['channels_replanned']}")
        for topo, a in avail.items():
            print(f"resilience/availability/{topo},0,{a:.3f}")
        print(f"resilience/yield_grid,0,n={grid['n_points']} "
              f"S={grid['n_scenarios']} mean_avail="
              f"{grid['availability_mean']:.3f} ({grid['mc_seconds']:.1f}s)")
        print(f"resilience/pass,0,{'PASS' if out['pass'] else 'FAIL'}")
    return out


if __name__ == "__main__":
    run()

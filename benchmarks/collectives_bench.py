"""Collective-schedule benchmark (paper Layer-B validation): cross-pod bytes
of the flat (bus-analog) vs TRINE hierarchical vs TRINE+int8 gradient
all-reduce, on the production multi-pod mesh geometry.

Analytical on the (2,16,16) 512-chip mesh (ring-algorithm byte accounting —
the same model validated against compiled HLO in tests/test_distributed.py),
for representative gradient sizes of the assigned archs.

Cross-pod serialization times are priced per fabric (`FABRIC_NAMES` presets
from `repro.core.fabric`): the `*_time_s` columns keep their historical
meaning (metallic ICI baseline — `DEFAULT_FABRIC`), and each schedule
additionally gets `{schedule}_time_{fabric}_s` columns including per-hop
link latency, so the schedule choice and the link design point can be
traded off in one table.
"""

from __future__ import annotations

import json
import time
from pathlib import Path


class _MeshLike:
    """Geometry stand-in (avoids forcing 512 devices in the bench process)."""

    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.empty(shape, dtype=object)


from repro.core.fabric import DEFAULT_FABRIC, get_fabric
from repro.parallel.collectives import collective_bytes_estimate

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"

GRAD_SIZES = {
    "yi-6b": 6.1e9,
    "yi-34b": 34.4e9,
    "deepseek-67b": 67.4e9,
    "grok-1-314b": 314e9,
}

FABRIC_NAMES = ("metallic_ici", "trine_siph", "tree_siph")

# cross-pod hop count per schedule (for the fabric link-latency term):
# flat = one global AR; trine = the cross-pod AR stage; trine_int8 = the
# int8-payload + f32-scale gathers of the cross-pod stage.
_N_CROSS_HOPS = {"flat": 1, "trine": 1, "trine_int8": 2}


def run(csv: bool = True) -> dict:
    mesh = _MeshLike((2, 16, 16), ("pod", "data", "model"))
    fabrics = [get_fabric(f) for f in FABRIC_NAMES]
    rows = []
    t0 = time.perf_counter()
    for arch, n in GRAD_SIZES.items():
        per_dev = n / 256  # FSDP-sharded grads within a pod (bf16)
        ests = {s: collective_bytes_estimate(int(per_dev), 2, mesh, s)
                for s in ("flat", "trine", "trine_int8")}
        row = {"arch": arch}
        for s, e in ests.items():
            row[f"{s}_cross_pod_gb"] = e["cross_pod_bytes"] / 1e9
            row[f"{s}_time_s"] = e["cross_pod_bytes"] / \
                DEFAULT_FABRIC.cross_pod_bw_bytes_per_s
            for fb in fabrics:
                row[f"{s}_time_{fb.name}_s"] = fb.collective_s(
                    e["cross_pod_bytes"], _N_CROSS_HOPS[s])
        row["trine_speedup"] = (ests["flat"]["cross_pod_bytes"]
                                / max(ests["trine"]["cross_pod_bytes"], 1))
        row["int8_speedup"] = (ests["flat"]["cross_pod_bytes"]
                               / max(ests["trine_int8"]["cross_pod_bytes"], 1))
        rows.append(row)
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    out = {"rows": rows}
    ARTIFACTS.mkdir(exist_ok=True)
    (ARTIFACTS / "collectives.json").write_text(json.dumps(out, indent=1))
    if csv:
        for r in rows:
            print(f"collectives/{r['arch']},{us:.1f},"
                  f"flat={r['flat_cross_pod_gb']:.3f}GB;"
                  f"trine={r['trine_cross_pod_gb']:.3f}GB;"
                  f"int8={r['trine_int8_cross_pod_gb']:.3f}GB;"
                  f"speedup={r['trine_speedup']:.1f}x/{r['int8_speedup']:.1f}x;"
                  f"int8_trine_siph={r['trine_int8_time_trine_siph_s']*1e3:.2f}ms")
    return out


if __name__ == "__main__":
    run()

"""Static-analysis gate: byte-compile + import-hygiene over the tree.

  python tools/lint.py            # or: python -m tools.lint

Two passes, no third-party dependencies required:

1. `compileall` — every file under the checked roots must byte-compile
   (syntax errors fail the gate before any test or benchmark runs).
2. pyflakes when it is installed; otherwise a vendored AST fallback that
   reports unused imports and `import *` usage.  The fallback is
   deliberately conservative: `__init__.py` files are exempt (re-export
   modules), a name appearing anywhere in the file source (including
   strings and `__all__`) counts as used, and lines carrying a `# noqa`
   marker are skipped.

`run()` returns {"ok", "engine", "findings", "n_files"} and is what
`benchmarks.run` folds into the required-check summary; `main()` prints
findings and exits non-zero when the gate fails.
"""

from __future__ import annotations

import ast
import compileall
import sys
from pathlib import Path
from typing import Dict, List

REPO = Path(__file__).resolve().parents[1]
DEFAULT_ROOTS = ("src", "benchmarks", "examples", "tools")


def _iter_sources(roots) -> List[Path]:
    out: List[Path] = []
    for root in roots:
        p = REPO / root
        if p.is_file():
            out.append(p)
        elif p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
    return out


def _fallback_check(path: Path) -> List[str]:
    """Vendored unused-import / import-star detector for when pyflakes is
    not installed.  A finding is "<file>:<line>: <message>"."""
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:  # compileall already flags it; keep a record
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    if path.name == "__init__.py":
        return []  # package re-export surface: unused imports are the point
    lines = src.splitlines()

    def _noqa(lineno: int) -> bool:
        return 0 < lineno <= len(lines) and "noqa" in lines[lineno - 1]

    imported: Dict[str, int] = {}
    findings: List[str] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = (a.asname or a.name).split(".")[0]
                if not _noqa(node.lineno):
                    imported.setdefault(name, node.lineno)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    if not _noqa(node.lineno):
                        findings.append(
                            f"{path}:{node.lineno}: import * from "
                            f"{node.module or '.'} hides unused names")
                    continue
                if not _noqa(node.lineno):
                    imported.setdefault(a.asname or a.name, node.lineno)

    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # pick up dotted roots like `os.path` from `import os`
            base = node
            while isinstance(base, ast.Attribute):
                base = base.value
            if isinstance(base, ast.Name):
                used.add(base.id)
    # a name mentioned in any string literal (doctests, __all__ built from
    # strings, jitted-function registries) counts as used — conservative
    text_blob = src
    for name, lineno in sorted(imported.items(), key=lambda kv: kv[1]):
        if name in used:
            continue
        if f'"{name}"' in text_blob or f"'{name}'" in text_blob:
            continue
        findings.append(f"{path}:{lineno}: unused import {name!r}")
    return findings


def run(roots=DEFAULT_ROOTS) -> Dict[str, object]:
    """Run both passes over `roots` (repo-relative).  Never raises."""
    files = _iter_sources(roots)
    compile_ok = True
    for root in roots:
        p = REPO / root
        if p.is_dir():
            compile_ok &= bool(compileall.compile_dir(
                str(p), quiet=2, force=False))
        elif p.is_file():
            compile_ok &= bool(compileall.compile_file(str(p), quiet=2))

    findings: List[str] = []
    try:
        from pyflakes.api import checkPath
        from pyflakes.reporter import Reporter
        import io
        engine = "pyflakes"
        for f in files:
            out, err = io.StringIO(), io.StringIO()
            checkPath(str(f), Reporter(out, err))
            findings.extend(x for x in out.getvalue().splitlines() if x)
            findings.extend(x for x in err.getvalue().splitlines() if x)
    except ImportError:
        engine = "fallback-ast"
        for f in files:
            findings.extend(_fallback_check(f))

    return {
        "ok": bool(compile_ok) and not findings,
        "compile_ok": bool(compile_ok),
        "engine": engine,
        "findings": findings,
        "n_files": len(files),
        "roots": list(roots),
    }


def main(argv=None) -> int:
    roots = (argv if argv else None) or DEFAULT_ROOTS
    res = run(tuple(roots))
    print(f"lint: engine={res['engine']} files={res['n_files']} "
          f"compile_ok={res['compile_ok']} findings={len(res['findings'])}")
    for f in res["findings"]:
        print(f"  {f}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

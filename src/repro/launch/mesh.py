"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not a module constant) so importing this
module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
tests and benches see the 1 real CPU device.

Mesh semantics (DESIGN.md §2):
  pod    — cross-pod axis (slow ICI/DCN links).  TRINE's "subnetwork" axis:
           the hierarchical collectives minimize stages crossing it.
  data   — intra-pod FSDP/data-parallel axis (the SWMR/SWSR "memory chiplet"
           axis: parameters live sharded here, all-gathered for compute,
           gradients reduce-scattered back).
  model  — tensor-parallel axis (compute chiplets).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count
    set by the test runner via subprocess env)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def has_pod_axis(mesh) -> bool:
    return "pod" in mesh.axis_names

"""Batched serving driver: continuous-batching style prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
      --batch 4 --prompt-len 64 --max-new 32

Serving loop: batch B prompts -> prefill -> greedy decode with a static-shape
KV cache; reports per-phase latency and tokens/s.  The full-scale path lowers
the same `serve_step` the dry-run proves against the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch) if args.reduced else C.get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params, _ = M.init(cfg, key)

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (b, s), 2, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    enc_out = None
    if cfg.encoder_layers:
        enc = jax.random.normal(key, (b, max(1, s // 4), cfg.d_model))
        batch["enc_embeds"] = enc
        enc_out = M.encode(cfg, params, enc)

    cache_len = s + args.max_new

    @jax.jit
    def prefill(p, bt):
        return M.prefill(cfg, p, bt, cache_len=cache_len)

    @jax.jit
    def step(p, cache, tok, pos):
        return M.serve_step(cfg, p, cache, tok, pos, enc_out=enc_out)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    total_new = b * args.max_new
    print(f"prefill: {t_prefill*1e3:.1f} ms for {b}x{s} tokens "
          f"({b*s/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms for {total_new} tokens "
          f"({total_new/max(t_decode,1e-9):.0f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"generated shape: {gen.shape}; sample: {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()

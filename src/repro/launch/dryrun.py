import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

  single-pod: (16, 16)    ("data", "model")        256 chips
  multi-pod : (2, 16, 16) ("pod", "data", "model") 512 chips

For each cell we lower the REAL step function (train_step with AdamW update,
prefill, or serve_step) against ShapeDtypeStruct inputs, compile, and record:
memory_analysis (fits?), cost_analysis (FLOPs/bytes), and the collective
schedule parsed from the partitioned HLO.  Artifacts land in
benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs as C
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel import sharding as S
from repro.parallel import actx
from repro.parallel import wire
from repro.runtime.trainer import make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def model_flops_per_device(cfg: ModelConfig, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training;
    2*N*D for inference steps (forward only).  Per-device."""
    n = cfg.active_param_count() if cfg.n_experts else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        total = 6.0 * n * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        total = 2.0 * n * d
    else:  # decode: one token per sequence
        d = shape.global_batch * 1
        total = 2.0 * n * d
    return total / n_devices


def _lower_cell(cfg: ModelConfig, shape, mesh, strategy=None, opt_dtype=None):
    """Returns the lowered step function for the cell."""
    strategy = strategy or cfg.parallel_strategy
    rules = S.rules_for(cfg, mesh, strategy)
    specs = C.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = adamw.OptConfig(
            state_dtype=opt_dtype or (
                "bfloat16" if "pod" in cfg.fsdp_axes else "float32"))
        params_shape, param_specs = M.init_abstract(cfg)
        pw = None
        if cfg.wire_bits:
            pw = wire.make_param_wire(cfg, mesh, rules, param_specs)
        step_fn = make_train_step(cfg, opt, param_wire=pw)
        state_shape = jax.eval_shape(
            lambda p: adamw.init_state(opt, p), params_shape)
        state_sh = S.enforce_divisibility(
            S.tree_shardings(mesh, adamw.state_specs(param_specs), rules),
            state_shape)
        batch_sh = S.train_batch_shardings(cfg, mesh, specs["batch"], strategy)
        return jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                       donate_argnums=(0,)).lower(state_shape, specs["batch"])

    params_shape, param_specs = M.init_abstract(cfg)
    param_sh = S.enforce_divisibility(
        S.tree_shardings(mesh, param_specs, rules), params_shape)

    if shape.kind == "prefill":
        def pf(params, batch):
            return M.prefill(cfg, params, batch, cache_len=shape.seq_len + 1)
        batch_sh = S.train_batch_shardings(cfg, mesh, specs["batch"], strategy)
        return jax.jit(pf, in_shardings=(param_sh, batch_sh)).lower(
            params_shape, specs["batch"])

    # decode / long_decode
    cache_shape, cache_specs = M.init_cache_abstract(cfg, shape.global_batch,
                                                      shape.seq_len)
    cache_sh = S.enforce_divisibility(
        S.cache_shardings(cfg, mesh, cache_specs, shape.global_batch, rules),
        cache_shape)
    tok_sh = S.train_batch_shardings(cfg, mesh, {"t": specs["tokens"]})["t"]
    ps = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if cfg.encoder_layers:
        def sv(params, cache, tokens, pos, enc_out):
            return M.serve_step(cfg, params, cache, tokens, pos, enc_out=enc_out)
        enc_sh = S.train_batch_shardings(cfg, mesh, {"e": specs["enc_out"]})["e"]
        return jax.jit(sv, in_shardings=(param_sh, cache_sh, tok_sh, ps, enc_sh),
                       donate_argnums=(1,)).lower(
            params_shape, specs["cache"], specs["tokens"], specs["pos"],
            specs["enc_out"])

    def sv(params, cache, tokens, pos):
        return M.serve_step(cfg, params, cache, tokens, pos)
    return jax.jit(sv, in_shardings=(param_sh, cache_sh, tok_sh, ps),
                   donate_argnums=(1,)).lower(
        params_shape, specs["cache"], specs["tokens"], specs["pos"])


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose=True,
             strategy=None, remat=None, opt_dtype=None, wire_bits=None,
             moe_dispatch=None) -> dict:
    cfg = C.get(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if wire_bits is not None:
        cfg = dataclasses.replace(cfg, wire_bits=wire_bits)
    if moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    shape = C.SHAPES[shape_name]
    skip = C.supports_shape(cfg, shape)
    out = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy or cfg.parallel_strategy,
           "remat": cfg.remat, "opt_dtype": opt_dtype,
           "wire_bits": cfg.wire_bits,
           "status": "skip", "skip_reason": skip}
    if skip:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {skip}")
        return out

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.perf_counter()
    strat = strategy or cfg.parallel_strategy
    dp = S.batch_axes(mesh, shape.global_batch, strat)
    with mesh, actx.activation_sharding(mesh, dp, seq_tp=(strat == "seq_tp"),
                                        wire_ok=(strat == "fsdp_all")):
        lowered = _lower_cell(cfg, shape, mesh, strategy, opt_dtype=opt_dtype)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    stats = H.analyze_hlo(hlo, n_dev)
    mflops = model_flops_per_device(cfg, shape, n_dev)

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    io_bytes = (mem_fields.get("argument_size_in_bytes") or 0) + \
               (mem_fields.get("output_size_in_bytes") or 0)
    terms = H.roofline(stats, cost, mflops, io_bytes=io_bytes)

    out.update({
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": mem_fields,
        "cost_flops": float(cost.get("flops", -1)),
        "cost_bytes": float(cost.get("bytes accessed", -1)),
        "hlo_stats": stats.to_json(),
        "roofline": terms.to_json(),
    })
    if verbose:
        per_dev_gb = (mem_fields.get("argument_size_in_bytes") or 0) / 2**30
        print(f"[ok] {arch:20s} x {shape_name:12s} x {mesh_kind:6s} "
              f"args={per_dev_gb:6.2f}GiB/dev "
              f"compute={terms.compute_s*1e3:8.2f}ms "
              f"memory={terms.memory_s*1e3:8.2f}ms "
              f"collective={terms.collective_s*1e3:8.2f}ms "
              f"-> {terms.bottleneck} (compile {t_compile:.0f}s)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(C.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--strategy", default=None,
                    choices=[None, "tp_fsdp", "fsdp_all", "seq_tp"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "full", "dots", "dots_all"])
    ap.add_argument("--opt-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--wire-bits", default=None, type=int,
                    help="int8 weight wire format (fsdp_all only)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "einsum", "index"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--continue-on-error", action="store_true", default=True)
    args = ap.parse_args()

    archs = list(C.ALIASES.keys()) if args.all or not args.arch else [args.arch]
    archs = sorted({C.ALIASES[a] for a in archs})
    shapes = list(C.SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if (args.mesh == "both" or args.all) else [args.mesh]

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}" + (
                    f"__{args.tag}" if args.tag else "")
                try:
                    res = run_cell(arch, shape, mesh_kind,
                                   strategy=args.strategy, remat=args.remat,
                                   opt_dtype=args.opt_dtype,
                                   wire_bits=args.wire_bits,
                                   moe_dispatch=args.moe_dispatch)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[ERROR] {tag}: {type(e).__name__}: {e}")
                    if not args.continue_on_error:
                        raise
                (ARTIFACTS / f"{tag}.json").write_text(json.dumps(res, indent=1))
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())

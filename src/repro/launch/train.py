"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \\
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ck

Full-scale configs need the production mesh (real TPUs); `--reduced` runs the
same code path end-to-end on this CPU container.  The trainer checkpoints
atomically and auto-resumes from the newest checkpoint in --ckpt.
"""

from __future__ import annotations

import argparse


from repro import configs as C
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--photonic-mac", action="store_true",
                    help="route linears through the photonic-MAC QAT numerics")
    ap.add_argument("--wire-bits", type=int, default=0,
                    help="int8/bf16 parameter wire format (8 or 16)")
    ap.add_argument("--moe-dispatch", choices=["einsum", "index"], default=None)
    ap.add_argument("--data-file", default=None,
                    help="mmap token corpus (.bin uint16); default synthetic")
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch) if args.reduced else C.get(args.arch)
    import dataclasses
    if args.photonic_mac:
        cfg = dataclasses.replace(cfg, use_photonic_mac=True)
    if args.wire_bits:
        cfg = dataclasses.replace(cfg, wire_bits=args.wire_bits)
    if args.moe_dispatch:
        cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    data = DataConfig(global_batch=args.batch, seq_len=args.seq)
    source = None
    if args.data_file:
        from repro.data.filesource import TokenFileSource
        source = TokenFileSource(cfg, data, args.data_file)

    trainer = Trainer(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                  total_steps=args.steps),
        data,
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every),
        mesh=mesh,
        resume=not args.no_resume,
        source=source,
    )
    out = trainer.run(args.steps)
    print(f"done: {out}")


if __name__ == "__main__":
    main()

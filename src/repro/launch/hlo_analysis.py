"""Roofline-term extraction from compiled SPMD executables.

`compiled.cost_analysis()` under-counts scanned programs: XLA's HLO cost
analysis counts a while-loop body ONCE, not times its trip count (verified on
this container: a 2-layer and an 8-layer lax.scan report identical FLOPs).
Since every model here scans its layers, we parse the post-partitioning HLO
text ourselves:

  1. split the module into computations,
  2. recover each while loop's trip count from the max integer constant in its
     condition computation (the induction bound),
  3. propagate call-site multipliers (body= x trip, condition/call/fusion x 1)
     from ENTRY,
  4. count dot FLOPs (2 * result_elems * contracted_dim) and collective bytes
     (ring-weighted by replica-group size) per computation x multiplier.

Wire-dtype correction: the CPU backend's FloatNormalization pass erases bf16
(verified here: even a bf16 *input* pinned replicated compiles to
`all-gather(f32 convert(bf16 param))`), and its fusion pass hoists dequants
ahead of gathers.  The TPU pipeline keeps bf16 collectives native and runs
CollectiveQuantizer (narrowing converts commute into collectives), so the
payload that crosses a real ICI link is the NARROW tensor.  We therefore
resolve each collective operand through one level of
convert/copy/bitcast/fusion producers: if a producer operand with the SAME
element count has a narrower dtype, the wire bytes are counted at that width.
`collective_bytes_raw` keeps the uncorrected number as the cross-check.

Raw cost_analysis numbers are kept in the artifacts as the uncorrected
cross-check.  Hardware constants (TPU v5e-class target, per assignment):
197 TFLOP/s bf16/chip ; 819 GB/s HBM ; ~50 GB/s/link ICI.  Those constants
are the `metallic_ici` default of `repro.core.fabric` — `roofline(...)`
accepts any other `Fabric` (a preset name or a co-design frontier point)
and prices the collective term against that design's cross-pod link
instead; `PEAK_FLOPS`/`HBM_BW`/`ICI_BW` remain as module aliases of the
default fabric for existing callers.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.fabric import DEFAULT_FABRIC, get_fabric

# back-compat aliases: the metallic default fabric's constants
PEAK_FLOPS = DEFAULT_FABRIC.peak_flops
HBM_BW = DEFAULT_FABRIC.hbm_bw_bytes_per_s
ICI_BW = DEFAULT_FABRIC.cross_pod_bw_bytes_per_s

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_dims(text: str) -> List[Tuple[str, int]]:
    """All (dtype, elems) shapes at the start of `text` (handles tuples)."""
    out = []
    head = text
    if head.startswith("("):
        head = head[:head.index(")")] if ")" in head else head
    else:
        sp = head.find(" ")
        head = head[:sp] if sp > 0 else head
    for m in _SHAPE_RE.finditer(head):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
        if not text.startswith("("):
            break
    return out


def _shape_bytes(text: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_dims(text))


def _shape_elems(text: str) -> int:
    s = _shape_dims(text)
    return s[0][1] if s else 0


def _dims_list(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    rhs: str


@dataclasses.dataclass
class _Comp:
    name: str
    ops: List[_Op]
    is_entry: bool = False


def _parse_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = _Comp(name=hdr.group(2), ops=[], is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(_Op(m.group(1), m.group(2)))
    return comps


_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_REFS = re.compile(
    r"(?:calls=|to_apply=|branch_computations=\{)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: _Comp) -> int:
    """Max integer constant in the loop condition — the induction bound."""
    best = 1
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.rhs):
            best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, _Comp]) -> Dict[str, float]:
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # single-computation module
        return {c: 1.0 for c in comps}

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 32:
            return
        mult[name] += m
        comp = comps[name]
        for op in comp.ops:
            wm = _WHILE_RE.search(op.rhs)
            if wm and " while(" in op.rhs:
                cond_name, body_name = wm.groups()
                trip = _trip_count(comps[cond_name]) if cond_name in comps else 1
                visit(cond_name, m, depth + 1)
                visit(body_name, m * trip, depth + 1)
                continue
            for ref in _CALL_REFS.finditer(op.rhs):
                sub = ref.group(1)
                if sub != name:
                    visit(sub, m, depth + 1)
            # conditional: branch_computations={%a, %b} — regex catches first;
            # catch the rest:
            bm = re.search(r"branch_computations=\{([^}]*)\}", op.rhs)
            if bm:
                for nm in bm.group(1).split(","):
                    nm = nm.strip().lstrip("%")
                    if nm and nm != name:
                        visit(nm, m, depth + 1)

    visit(entry.name, 1.0)
    return mult


def _group_size(rhs: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rhs)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip() != ""]))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rhs)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    dot_bytes: float             # Σ dot operand+result bytes × multiplier
    op_result_bytes: float       # Σ ALL result bytes × multiplier (upper bound)
    collective_bytes: float      # ring-weighted per-device wire bytes
    collective_op_bytes: Dict[str, float]
    collective_op_counts: Dict[str, int]
    max_trip: int
    collective_dtype_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)    # wire bytes per payload dtype (diagnostics)
    collective_bytes_raw: float = 0.0   # uncorrected (compiled-HLO dtypes)

    def to_json(self):
        return dataclasses.asdict(self)


def _operand_names(rhs: str) -> List[str]:
    """Operand op names of an instruction.  Compiled `as_text()` prints each
    operand with its shape inline (``fusion(f32[2048]{0} %x, s8[64]{0} %q)``),
    so take the LAST whitespace token of each argument — on shape-less
    synthetic HLO that token is the whole argument."""
    args = re.search(r"\(([^)]*)\)", rhs)
    if not args:
        return []
    return [a.strip().split()[-1].lstrip("%")
            for a in args.group(1).split(",") if a.strip()]


_PASSTHROUGH = re.compile(
    r"(^|\s)(convert|copy|bitcast|fusion|reshape|transpose|slice|dynamic-slice)\(")

# collectives that move data without reducing — narrowing converts commute
# through these (XLA-TPU CollectiveQuantizer); all-reduce / reduce-scatter
# payload dtype changes the reduction numerics, so those are never corrected.
_MOVEMENT_COLLECTIVES = ("all-gather", "all-to-all", "collective-permute")

_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_CONVERT_RES = re.compile(r"^\s*(\w+)\[([\d,]*)\]\S*\s+convert\(")


def _fusion_interior_width(rhs, comps, elems, width):
    """The CPU backend hides f32<->bf16 convert pairs inside kLoop fusions
    (`convert_convert_fusion`); the narrow type those converts witness is the
    dtype a TPU build keeps live.  Scan the called computation for converts
    over `elems` elements narrower than `width`."""
    m = _CALLS_RE.search(rhs)
    if not m or m.group(1) not in comps:
        return width
    for op in comps[m.group(1)].ops:
        cm = _CONVERT_RES.search(op.rhs)
        if not cm:
            continue
        dt, dims = cm.group(1), cm.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if n == elems and _DTYPE_BYTES[dt] < width:
            width = _DTYPE_BYTES[dt]
    return width


def _producer_narrow_width(op_rhs, shapes, comps, elems, width, depth=3):
    """Chase convert/fusion/slice producers: a producer operand with at least
    `elems` elements and a narrower dtype (or a fusion whose interior
    narrows) means the wire payload is (a slice of) that narrow tensor."""
    width = _fusion_interior_width(op_rhs, comps, elems, width)
    frontier = [op_rhs]
    for _ in range(depth):
        nxt = []
        for rhs in frontier:
            for name in _operand_names(rhs):
                prod = shapes.get(name)
                if prod is None:
                    continue
                pdims = _shape_dims(prod)
                if not pdims:
                    continue
                pdt, pelems = pdims[0]
                if pelems >= elems and _DTYPE_BYTES[pdt] < width:
                    width = _DTYPE_BYTES[pdt]
                if pelems >= elems and _PASSTHROUGH.search(" " + prod):
                    width = _fusion_interior_width(prod, comps, elems, width)
                    nxt.append(prod)
        frontier = nxt
    return width


def _consumer_narrow_width(coll_name, users, shapes, comps, elems, width,
                           depth=3):
    """If every consumer branch of the collective result narrows it through
    elem-preserving convert/copy chains, the TPU pipeline sinks the convert
    into the collective (CollectiveQuantizer) — the wire payload is the
    narrow dtype.  BFS through passthrough consumers (looking inside fusion
    bodies); any branch that consumes at full width pins the wire wide."""
    branch_widths = []

    def visit(name, w, d):
        consumers = users.get(name, ())
        if not consumers:
            branch_widths.append(w)   # dead/root result — no wider need
            return
        for uname, urhs in consumers:
            udims = _shape_dims(urhs)
            if not udims:
                branch_widths.append(w)
                continue
            udt, uelems = udims[0]
            passthrough = bool(_PASSTHROUGH.search(" " + urhs))
            inner = _fusion_interior_width(urhs, comps, elems, w)
            if inner < w:
                branch_widths.append(inner)               # narrowed in-body
            elif uelems == elems and passthrough and _DTYPE_BYTES[udt] < w:
                branch_widths.append(_DTYPE_BYTES[udt])   # narrowed here
            elif uelems == elems and passthrough and d < depth:
                visit(uname, w, d + 1)                    # chase onward
            else:
                branch_widths.append(w)                   # consumed as-is
    visit(coll_name, width, 0)
    return max(branch_widths) if branch_widths else width


def _wire_dtype_bytes(op_rhs: str, shapes: Dict[str, str], comps):
    dims = _shape_dims(op_rhs)
    if not dims:
        return 0, 0
    dt, elems = dims[0]
    width = _DTYPE_BYTES[dt]
    return elems, _producer_narrow_width(op_rhs, shapes, comps, elems, width)


def analyze_hlo(hlo: str, n_devices: int) -> HloStats:
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    shapes: Dict[str, str] = {}
    users: Dict[str, List[str]] = {}
    for comp in comps.values():
        for op in comp.ops:
            shapes[op.name] = op.rhs
    for comp in comps.values():
        for op in comp.ops:
            for a in _operand_names(op.rhs):
                if a in shapes:
                    users.setdefault(a, []).append((op.name, op.rhs))

    dot_flops = 0.0
    dot_bytes = 0.0
    result_bytes = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    coll_dtype: Dict[str, float] = {}
    total_coll = 0.0
    total_coll_raw = 0.0
    max_trip = 1

    for comp in comps.values():
        m = mult.get(comp.name, 1.0)
        if m <= 0:
            continue
        max_trip = max(max_trip, int(m))
        for op in comp.ops:
            rhs = op.rhs
            result_bytes += _shape_bytes(rhs) * m

            if " dot(" in rhs or rhs.startswith("dot("):
                out_elems = _shape_elems(rhs)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                contracted = 1
                operand_bytes = 0.0
                args = re.search(r"\(([^)]*)\)", rhs)
                if args:
                    names = [a.strip().split()[-1].lstrip("%")
                             for a in args.group(1).split(",") if a.strip()]
                    # operand bytes at their TRUE dtype: the CPU backend wraps
                    # bf16 dot operands in f32 convert-pair fusions (see
                    # module docstring); a TPU build reads bf16 from HBM.
                    for a in names:
                        elems, w = _wire_dtype_bytes(shapes.get(a, ""), shapes,
                                                     comps)
                        operand_bytes += elems * w
                    if cm and names:
                        lhs_dims = _dims_list(shapes.get(names[0], ""))
                        for d in cm.group(1).split(","):
                            if d and int(d) < len(lhs_dims):
                                contracted *= lhs_dims[int(d)]
                dot_flops += 2.0 * out_elems * contracted * m
                # result bytes at the dtype that actually reaches HBM: the
                # f32 MXU accumulator is cast to bf16 in the consumer fusion
                # before the write (consumer-narrowing, methodology note 2)
                res_w = _DTYPE_BYTES.get(
                    _shape_dims(rhs)[0][0], 4) if _shape_dims(rhs) else 4
                res_w = _consumer_narrow_width(op.name, users, shapes, comps,
                                               out_elems, res_w)
                dot_bytes += (operand_bytes + out_elems * res_w) * m
                continue

            kind = None
            for c in _COLLECTIVES:
                if re.search(rf"(^|\s){c}(-start)?\(", rhs):
                    kind = c
                    break
            if kind is None:
                continue
            movement = kind in _MOVEMENT_COLLECTIVES
            operand_bytes = 0
            operand_bytes_c = 0.0
            res_dims = _shape_dims(rhs)
            res_elems = res_dims[0][1] if res_dims else 0
            for a in _operand_names(rhs):
                prod = shapes.get(a, "")
                operand_bytes += _shape_bytes(prod)
                dims_a = _shape_dims(prod)
                if not dims_a:
                    continue
                dt_a, elems = dims_a[0]
                full_w = _DTYPE_BYTES[dt_a]
                pw = _producer_narrow_width(prod, shapes, comps, elems, full_w)
                cw = full_w
                if res_elems:
                    cw = _consumer_narrow_width(op.name, users, shapes, comps,
                                                res_elems, full_w)
                if movement:
                    # converts commute through pure data movement
                    w = min(pw, cw)
                else:
                    # reductions: narrow ONLY when both sides witness the
                    # narrow dtype — the CPU FloatNormalization sandwich
                    # around a semantically-bf16 psum.  A genuine f32
                    # reduction (f32 grads) keeps full width.
                    w = max(pw, cw)
                operand_bytes_c += elems * w
            res = _shape_bytes(rhs)
            ratio = (operand_bytes_c / operand_bytes) if operand_bytes else 1.0
            n = _group_size(rhs, n_devices)
            if kind == "all-reduce":
                moved = 2 * (n - 1) / max(n, 1) * operand_bytes
            elif kind == "all-gather":
                moved = (n - 1) / max(n, 1) * max(res, operand_bytes)
            elif kind == "reduce-scatter":
                moved = (n - 1) / max(n, 1) * operand_bytes
            elif kind == "all-to-all":
                moved = (n - 1) / max(n, 1) * max(operand_bytes, res)
            else:
                moved = operand_bytes
            moved_c = moved * ratio
            coll_bytes[kind] += moved_c * m
            coll_counts[kind] += int(m)
            total_coll += moved_c * m
            total_coll_raw += moved * m
            dts = _shape_dims(rhs)
            dt = dts[0][0] if dts else "?"
            if ratio < 0.999:
                bits = max(1, round(8 * _DTYPE_BYTES.get(dt, 4) * ratio))
                dt = f"{dt}->w{bits}"
            coll_dtype[dt] = coll_dtype.get(dt, 0.0) + moved_c * m

    return HloStats(
        dot_flops=dot_flops,
        dot_bytes=dot_bytes,
        op_result_bytes=result_bytes,
        collective_bytes=total_coll,
        collective_op_bytes=coll_bytes,
        collective_op_counts=coll_counts,
        max_trip=max_trip,
        collective_dtype_bytes=coll_dtype,
        collective_bytes_raw=total_coll_raw,
    )


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # trip-corrected dot FLOPs (per device)
    hbm_bytes: float              # trip-corrected result-bytes traffic proxy
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_frac: float
    raw_cost_flops: float         # uncorrected cost_analysis (cross-check)
    raw_cost_bytes: float
    fabric: str = "metallic_ici"  # name of the fabric that priced the terms

    def to_json(self):
        return dataclasses.asdict(self)


def roofline(stats: HloStats, cost: dict,
             model_flops_per_device: float, io_bytes: float = 0.0,
             fabric=None) -> RooflineTerms:
    """Memory term = dot operand/result traffic + program I/O (params/state
    read+written once).  Elementwise chains are assumed fused into the dots
    (the TPU compiler does); `op_result_bytes` is kept as the no-fusion upper
    bound in the artifact.

    `fabric` prices the terms against one network design point (a
    `repro.core.fabric.Fabric`, a preset name like "trine_siph", or None for
    the metallic default).  The collective term charges the cross-pod link
    plus the fabric's fixed per-collective latency (MZI switching /
    arbitration); the default fabric has zero per-collective latency and the
    historical constants, so results under it are byte-identical to the
    pre-fabric path."""
    fb = get_fabric(fabric)
    flops = stats.dot_flops
    hbm = stats.dot_bytes + io_bytes
    compute_s = fb.compute_s(flops)
    memory_s = fb.memory_s(hbm)
    collective_s = fb.collective_s(
        stats.collective_bytes,
        float(sum(stats.collective_op_counts.values())))
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, collective_bytes=stats.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops_per_device,
        useful_flops_frac=(model_flops_per_device / flops) if flops else 0.0,
        raw_cost_flops=float(cost.get("flops", -1.0)),
        raw_cost_bytes=float(cost.get("bytes accessed", -1.0)),
        fabric=fb.name,
    )

from repro.serve.engine import ContinuousBatcher, Request  # noqa: F401

"""Continuous-batching serving engine (iteration-level scheduling).

vLLM-style slot scheduler on static JAX shapes: a fixed pool of `n_slots`
cache slots decodes in lockstep, but each slot sits at its OWN position
(`serve_step` takes a (B,) position vector); finished requests free their
slot, which is immediately refilled by prefilling the next queued request
into that slot's cache rows.  Two compiled programs total — one prefill per
prompt-length bucket, one decode step — no recompilation as requests churn.

Why this matters here: decode_32k/long_500k roofline cells are collective/
memory-bound, i.e. throughput comes from batching; continuous batching keeps
the batch full under ragged request lengths (the paper's bandwidth-matching
argument applied to serving: keep the provisioned lanes busy).

Cache slot surgery is structure-agnostic: every cache leaf's row-0 dim is
`ratio * n_slots` for integer ratio (pure batch for attention/mamba, B*H for
mLSTM), so slot `i` owns rows [i*ratio, (i+1)*ratio).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric import degrade, get_fabric
from repro.core.faults import FabricUnusableError, FaultScenario
from repro.core.planner import plan_collective_channels
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _slot_update(cache_tree, slot_tree, slot: int, n_slots: int):
    """Write `slot_tree` (batch=1 cache) into slot `slot` of the pooled
    cache (batch=n_slots).  Cache leaves are layer-stacked: (L, B*ratio, ...)
    — batch lives on axis 1 (ratio>1 for fused batch*heads leaves)."""
    def leaf(pool, one):
        ratio = pool.shape[1] // n_slots
        assert one.shape[1] == ratio, (pool.shape, one.shape, n_slots)
        return jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot * ratio, axis=1)
    return jax.tree.map(leaf, cache_tree, slot_tree)


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_len: int,
                 eos_id: Optional[int] = None, prompt_bucket: int = 16,
                 fabric=None, decode_window_s: float = 2e-3):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id = eos_id
        # recurrent states integrate every input token — right-padding would
        # corrupt them, so recurrent families prefill at exact length
        # (one compile per distinct prompt length instead of per bucket)
        self.bucket = 1 if cfg.family in ("ssm", "hybrid") else prompt_bucket
        self.cache, _ = M.init_cache(cfg, n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int32)       # next write position
        self.last_tok = np.zeros(n_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._next_rid = 0

        self._decode = jax.jit(self._decode_impl)
        self._prefills: Dict[int, callable] = {}     # per padded length

        # modeled photonic fabric under the per-iteration tensor-parallel
        # collectives (2 all-reduces of bf16 activations per layer, the
        # whole decode batch); replanned on injected faults
        self.fabric = None if fabric is None else get_fabric(fabric)
        self.decode_window_s = decode_window_s
        self.collective_channels = None
        self.net_stats = {"decode_iters": 0, "modeled_net_s": 0.0,
                          "fault_iter": None, "replans": 0}
        if self.fabric is not None:
            self._replan()

    # ---- fault-epoch hook --------------------------------------------
    def _iter_wire_bytes(self) -> float:
        return float(self.cfg.n_layers * 2 * self.n_slots
                     * self.cfg.d_model * 2)

    def _replan(self) -> None:
        if self.fabric.cross_pod_bw_bytes_per_s <= 0:
            raise FabricUnusableError(
                f"fabric {self.fabric.name!r} has no surviving bandwidth; "
                f"decode collectives cannot be scheduled")
        self.collective_channels = plan_collective_channels(
            self._iter_wire_bytes(), self.decode_window_s,
            fabric=self.fabric, min_chunk_bytes=1 << 10)
        self._net_s_per_iter = self.fabric.collective_s(
            self._iter_wire_bytes(),
            n_collectives=self.cfg.n_layers * 2)
        self.net_stats["replans"] += 1

    def inject_fault(self, scenario: FaultScenario) -> None:
        """Degrade the serving fabric and replan — decode continues at the
        (modeled) reduced throughput, or hard-fails when nothing survives."""
        if self.fabric is None:
            raise ValueError("batcher has no fabric to degrade")
        self.fabric = degrade(self.fabric, scenario)
        self._replan()
        self.net_stats["fault_iter"] = self.net_stats["decode_iters"]

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int) -> Request:
        r = Request(self._next_rid, list(prompt), max_new)
        self._next_rid += 1
        self.queue.append(r)
        return r

    # ------------------------------------------------------------------
    def _decode_impl(self, params, cache, toks, pos):
        return M.serve_step(self.cfg, params, cache, toks, pos)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefills:
            cfg, max_len = self.cfg, self.max_len

            def pf(params, tokens):
                return M.prefill(cfg, params, {"tokens": tokens},
                                 cache_len=max_len)
            self._prefills[plen] = jax.jit(pf)
        return self._prefills[plen]

    def _admit(self, slot: int, req: Request):
        """Prefill prompt[:-1] into the slot, then seed decode with the last
        prompt token at pos len-1: the first decode step processes that token
        fresh (idempotent for KV caches, single-count for recurrent states)
        and yields the first generated token.  Right-pad KV beyond the real
        length is position-masked and overwritten as decode advances."""
        core = req.prompt[:-1]
        if not core:
            # empty prefill: reset the slot to the zero/init cache
            fresh, _ = M.init_cache(self.cfg, 1, self.max_len)
            self.cache = _slot_update(self.cache, fresh, slot, self.n_slots)
        else:
            plen = max(self.bucket,
                       ((len(core) + self.bucket - 1) // self.bucket)
                       * self.bucket)
            assert plen < self.max_len, (plen, self.max_len)
            toks = np.zeros((1, plen), np.int32)
            toks[0, :len(core)] = core
            _, slot_cache = self._prefill_fn(plen)(self.params,
                                                   jnp.asarray(toks))
            self.cache = _slot_update(self.cache, slot_cache, slot,
                                      self.n_slots)
        self.slot_req[slot] = req
        self.pos[slot] = len(req.prompt) - 1
        self.last_tok[slot] = req.prompt[-1]

    # ------------------------------------------------------------------
    def run(self, fault_at_iter: Optional[int] = None,
            fault_scenario: Optional[FaultScenario] = None) -> List[Request]:
        """Drain the queue; returns all finished requests.  With
        `fault_at_iter`, `fault_scenario` is injected before that decode
        iteration (0-based) — the modeled network time per iteration rises
        and `net_stats` records the fault point."""
        finished: List[Request] = []
        while self.queue or any(r is not None for r in self.slot_req):
            if (fault_at_iter is not None
                    and self.net_stats["decode_iters"] == fault_at_iter
                    and self.net_stats["fault_iter"] is None):
                self.inject_fault(fault_scenario)
            # admit into free slots
            for s in range(self.n_slots):
                if self.slot_req[s] is None and self.queue:
                    self._admit(s, self.queue.pop(0))
            # lockstep decode at per-slot positions
            toks = jnp.asarray(self.last_tok[:, None])
            pos = jnp.asarray(self.pos)
            logits, self.cache = self._decode(self.params, self.cache,
                                              toks, pos)
            self.net_stats["decode_iters"] += 1
            if self.fabric is not None:
                self.net_stats["modeled_net_s"] += self._net_s_per_iter
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
            for s in range(self.n_slots):
                req = self.slot_req[s]
                if req is None:
                    continue
                tok = int(nxt[s])
                req.out.append(tok)
                self.pos[s] += 1
                self.last_tok[s] = tok
                hit_eos = self.eos_id is not None and tok == self.eos_id
                if (len(req.out) >= req.max_new or hit_eos
                        or self.pos[s] >= self.max_len - 1):
                    req.done = True
                    finished.append(req)
                    self.slot_req[s] = None
        return finished

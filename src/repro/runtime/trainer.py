"""Fault-tolerant training runtime.

Builds the jitted, sharded train step (GSPMD over the production mesh or
plain jit on one device), wires the data pipeline, checkpoints step-atomically
and resumes bitwise-identically, injects/absorbs failures, and accounts
stragglers via the deadline policy.

train_step = forward (chunked CE) -> backward -> AdamW update, donated state.
Gradient synchronization is GSPMD-implicit by default; the TRINE hierarchical
/ compressed schedules in `repro.parallel.collectives` are exercised by the
manual-DP path (`grad_sync="trine"|"trine_int8"`) used in tests and the
collective benchmarks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core.fabric import degrade, get_fabric, overlapped_step_s
from repro.core.faults import FabricUnusableError, FaultScenario
from repro.core.planner import plan_collective_channels
from repro.data.pipeline import DataConfig, DeadlineMonitor, SyntheticLM
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel import sharding as S


class FailureInjected(RuntimeError):
    """Raised by the failure hook to simulate a node loss mid-run."""


def _split_microbatches(batch: Dict[str, jax.Array], accum: int):
    """(B, ...) leaves -> (accum, B/accum, ...); the (3,B,S) M-RoPE positions
    leaf splits on axis 1."""
    def leaf(x):
        if x.ndim >= 3 and x.shape[0] == 3:          # M-RoPE positions
            return jnp.moveaxis(
                x.reshape(3, accum, -1, *x.shape[2:]), 1, 0)
        return x.reshape(accum, -1, *x.shape[1:])
    return jax.tree.map(leaf, batch)


def make_train_step(cfg: ModelConfig, opt: adamw.OptConfig, param_wire=None,
                    accum_steps: int = 1):
    """`param_wire` (repro.parallel.wire.ParamWire) puts the narrow payload
    on the parameter all-gathers: scanned stacks cross the wire as int8
    pairs dequantized inside the scan body; gradients flow back to the f32
    masters through the zero-delta carrier (see wire.py docstring).

    `accum_steps` > 1 runs gradient accumulation: the global batch is split
    into microbatches scanned sequentially, gradients averaged, ONE optimizer
    update — the standard way to hold global batch fixed while per-device
    memory shrinks (or devices are lost: the elastic path re-plans accum)."""

    def grads_of(params_or_carrier, batch, loss_closure):
        return jax.value_and_grad(loss_closure, has_aux=True)(params_or_carrier)

    def step_fn(state: adamw.TrainState, batch: Dict[str, jax.Array]):
        if param_wire is None:
            diff_var = state.params
            def loss_of(v, mb):
                return M.loss_fn(cfg, v, mb)
        else:
            qtree = param_wire.quantize(state.params)   # outside AD, once
            diff_var = param_wire.carrier(state.params)
            def loss_of(v, mb):
                return M.loss_fn(cfg, param_wire.graft(qtree, v), mb)

        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda v: loss_of(v, batch), has_aux=True)(diff_var)
        else:
            mbs = _split_microbatches(batch, accum_steps)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (l, mets), g = jax.value_and_grad(
                    lambda v: loss_of(v, mb), has_aux=True)(diff_var)
                return (jax.tree.map(jnp.add, g_sum, g), l_sum + l), mets

            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                 diff_var)
            (g_sum, l_sum), mets = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = l_sum / accum_steps
            metrics = jax.tree.map(lambda m: jnp.mean(m), mets)

        new_state = adamw.apply_updates(opt, state, grads)
        metrics = dict(metrics, loss=loss,
                       grad_norm=adamw.global_norm(grads))
        return new_state, metrics
    return step_fn


def build_sharded_step(cfg: ModelConfig, opt: adamw.OptConfig, mesh,
                       param_specs, batch_example):
    """jit the train step with NamedShardings over `mesh` (None -> plain jit)."""
    if mesh is None:
        return jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    rules = S.rules_for(cfg, mesh)
    pw = None
    if cfg.wire_bits:
        from repro.parallel import wire as _wire
        pw = _wire.make_param_wire(cfg, mesh, rules, param_specs)
    step_fn = make_train_step(cfg, opt, param_wire=pw)
    state_sh = S.tree_shardings(mesh, adamw.state_specs(param_specs), rules)
    batch_sh = S.train_batch_shardings(cfg, mesh, batch_example)
    return jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                   donate_argnums=(0,)), state_sh, batch_sh


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_deadline_s: float = 1e9
    seed: int = 0
    overlap_window_s: float = 50e-3   # compute window the gradient collective
                                      # hides under (channel planning)


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: adamw.OptConfig,
                 data: DataConfig, tcfg: TrainerConfig,
                 mesh=None, resume: bool = True, source=None, fabric=None):
        self.cfg, self.opt, self.data_cfg, self.tcfg = cfg, opt, data, tcfg
        self.mesh = mesh
        self.source = source if source is not None else SyntheticLM(cfg, data)
        key = jax.random.PRNGKey(tcfg.seed)
        params, self.param_specs = M.init(cfg, key)
        self.state = adamw.init_state(opt, params)
        self.state_sh = None

        if mesh is None:
            self._step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
        else:
            self._step, self.state_sh, _ = build_sharded_step(
                cfg, opt, mesh, self.param_specs, self.source.batch_at(0))
            self.state = jax.device_put(self.state, self.state_sh)

        self.start_step = 0
        if resume:
            # corrupt/truncated latest checkpoints (bad SHA1, missing
            # manifest) are dropped and the previous retained step restored
            restored = store.restore_latest_valid(tcfg.ckpt_dir, self.state,
                                                  self.state_sh)
            if restored is not None:
                self.state, self.start_step = restored[0], int(restored[1])

        # modeled photonic fabric under the data-parallel gradient collective:
        # channel plan + exposed network time per step, replanned on faults
        self.fabric = None if fabric is None else get_fabric(fabric)
        self.collective_channels = None
        self.net_s = 0.0
        if self.fabric is not None:
            self._grad_bytes = 4.0 * sum(
                int(np.prod(np.shape(l)))
                for l in jax.tree.leaves(self.state.params))
            self._replan()

        self.monitor = DeadlineMonitor(tcfg.straggler_deadline_s)
        self.history: list = []

    # ---- fault-epoch hook -------------------------------------------------
    def _replan(self) -> None:
        """(Re)plan the gradient-collective channels against the current
        fabric and refresh the modeled exposed network time per step.
        Raises FabricUnusableError when the fabric cannot carry the
        collective at all (the hard-fail path)."""
        if self.fabric.cross_pod_bw_bytes_per_s <= 0:
            raise FabricUnusableError(
                f"fabric {self.fabric.name!r} has no surviving bandwidth; "
                f"the gradient collective cannot be scheduled")
        w = self.tcfg.overlap_window_s
        self.collective_channels = plan_collective_channels(
            self._grad_bytes, w, fabric=self.fabric, max_channels=64)
        self.net_s = overlapped_step_s(
            w, self._grad_bytes, self.fabric, self.collective_channels) - w

    def inject_fault(self, scenario: FaultScenario) -> None:
        """Degrade the fabric under `scenario` and replan the collective —
        training continues at the (modeled) reduced throughput, or hard-fails
        with FabricUnusableError when nothing survives."""
        if self.fabric is None:
            raise ValueError("trainer has no fabric to degrade")
        self.fabric = degrade(self.fabric, scenario)
        self._replan()

    def run(self, steps: int, fail_at: Optional[int] = None,
            quiet: bool = False, fault_at: Optional[int] = None,
            fault_scenario: Optional[FaultScenario] = None) -> Dict[str, Any]:
        t0 = time.perf_counter()
        for step in range(self.start_step, steps):
            if fault_at is not None and step + 1 == fault_at:
                self.inject_fault(fault_scenario)
            fetch_t0 = time.perf_counter()
            batch = self.source.batch_at(step)
            delivery = time.perf_counter() - fetch_t0
            if not self.monitor.admit(delivery):
                continue  # straggler drop: skip this host's contribution

            self.state, metrics = self._step(self.state, batch)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == steps:
                store.save(self.tcfg.ckpt_dir, step + 1, self.state,
                           keep=self.tcfg.keep)
            if fail_at is not None and step + 1 == fail_at:
                raise FailureInjected(f"injected node failure at step {step + 1}")
            if not quiet and (step + 1) % self.tcfg.log_every == 0:
                print(f"step {step+1}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            row = {k: float(v) for k, v in metrics.items()}
            row["step"] = step + 1
            if self.fabric is not None:
                row["net_s"] = self.net_s
            self.history.append(row)
        result = {
            "final_step": steps,
            "wall_s": time.perf_counter() - t0,
            "last_loss": self.history[-1]["loss"] if self.history else None,
            "straggler": dataclasses.asdict(self.monitor.stats),
        }
        if self.fabric is not None:
            result["fabric"] = self.fabric.name
            result["collective_channels"] = self.collective_channels
            result["net_s"] = self.net_s
        return result


def run_with_restarts(make_trainer, total_steps: int, fail_at=(),
                      **run_kwargs):
    """Supervisor loop: on FailureInjected (or a real crash in production),
    rebuild the trainer — which restores the latest checkpoint — and continue.
    Returns the last trainer, with `history` merged across segments so
    post-restart reports cover the full run (steps replayed after a restore
    keep only their re-executed rows — each step appears exactly once)."""
    pending = list(fail_at)
    prior: list = []
    while True:
        tr = make_trainer()
        # drop first-execution rows of steps the restored trainer will replay
        prior = [h for h in prior if h.get("step", 0) <= tr.start_step]
        try:
            tr.run(total_steps, fail_at=pending[0] if pending else None,
                   quiet=True, **run_kwargs)
            tr.history = prior + tr.history
            return tr
        except FailureInjected:
            prior = prior + tr.history
            pending.pop(0)
            continue

from repro.runtime.trainer import Trainer, TrainerConfig, FailureInjected, run_with_restarts

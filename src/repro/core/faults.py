"""Physical fault model for the photonic interposer fabric.

The paper's case for 2.5D photonic interposers rests on links that are
physically fragile in ways metallic ICI is not: microring resonators drift
with temperature and process variation, laser banks age and fail, and a dead
gateway chiplet severs whatever sat behind it.  This module expresses those
failure modes as **columnar perturbations** over the same struct-of-arrays
columns the sweep engine already evaluates, so a batch of fault scenarios
composes with `sweep_chunked` / `pareto_search` for Monte-Carlo yield and
availability analysis over 1e5+-point grids.

Fault modes and where they act
------------------------------

Input-column perturbations (seen by the topology kernels, so loss-dependent
laser sizing reacts):

  drift_db        thermal/process drift adds insertion loss per MZI stage
                  (``mzi.insertion_loss_db`` += drift_db)
  tuning_factor   drifted rings need more thermal trimming
                  (``mr.tuning_power_w`` *= tuning_factor, >= 1)
  wpe_factor      laser aging degrades wall-plug efficiency
                  (``laser.wall_plug_efficiency`` *= wpe_factor, <= 1)

Post-kernel survival derating (applied to the emitted MODEL_FIELDS — dead
hardware stays on the waveguide, so worst-path loss and ring counts do NOT
improve; only usable bandwidth shrinks):

  dead_lambda_frac     fraction of wavelengths lost to dead microrings:
                       scales usable bandwidth and active wavelength count.
  failed_laser_banks   ABSOLUTE count of dead laser banks.  A design with
                       one bank (Tree) dies outright at the first failure;
                       TRINE's K banks lose K-th fractions — the redundancy
                       argument made quantitative.
  failed_gateways      ABSOLUTE count of dead gateway chiplets.  TRINE loses
                       the whole subnetwork behind each dead gateway (blast
                       radius of its SWMR tree); bus topologies (SPACX /
                       SPRINT) and the electrical mesh lose ports
                       proportionally.

Monotonicity by construction: every knob can only raise loss, raise static
power, or shrink bandwidth, so latency / energy / EDP are monotone
non-improving in fault severity (the invariant resilience_bench checks).
Raw `power_w` is NOT monotone — a dead network has no dynamic power — so it
is deliberately excluded from the invariant.

Entry points
------------

  FaultScenario            one scenario (scalars) or a batch ((S, 1) arrays)
  FaultModel               failure *rates*; `.expected()` gives the
                           deterministic mean scenario for degradation
                           curves, `.sample(n)` draws a Monte-Carlo batch,
                           `.scale(severity)` scales every rate
  degraded_network_columns the fault-aware mirror of the sweep engine's
                           network-column builder (per-topology kernels +
                           survival derating); plugs into `sweep_chunked` /
                           `pareto_search` via `faulted_columns_fn`
  evaluate_degraded        batch-of-one convenience: metrics of one design
                           under one scenario (or a scenario batch)
  AvailabilityReducer /    chunked Monte-Carlo yield columns per design
  availability_search      point: expected-degraded-EDP and P(EPB <= budget)
  FabricUnusableError      the hard-fail signal: a degraded fabric that
                           cannot carry the collective at all
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.power import Traffic
from repro.core.topology import (
    MODEL_FIELDS,
    NetworkParams,
    TOPOLOGY_ARRAYS,
    params_columns,
)
from repro.core.sweep import (
    ChunkReducer,
    DEFAULT_TOPOLOGIES,
    GridSpec,
    SweepChunk,
    evaluate_columns,
    sweep_chunked,
)

__all__ = [
    "FaultScenario", "FaultModel", "FabricUnusableError", "HEALTHY",
    "degrade_device_columns", "degraded_network_columns",
    "FaultedColumns", "faulted_columns_fn", "evaluate_degraded",
    "AvailabilityReducer", "availability_search",
]


class FabricUnusableError(RuntimeError):
    """A degraded fabric cannot carry the collective at all (zero surviving
    bandwidth) — the hard-fail path for trainer/serving replans."""


# scenario fields, in one place so batching/broadcast helpers stay in sync
_SCENARIO_FIELDS = ("dead_lambda_frac", "failed_laser_banks",
                    "failed_gateways", "wpe_factor", "drift_db",
                    "tuning_factor")


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One concrete fault state.  Every field is a scalar or an (S, 1) array
    (a batch of S scenarios — the extra trailing axis broadcasts against the
    config axis, giving (S, N) metrics from an N-point grid)."""

    dead_lambda_frac: object = 0.0   # in [0, 1]
    failed_laser_banks: object = 0.0  # absolute count (may be fractional mean)
    failed_gateways: object = 0.0     # absolute count
    wpe_factor: object = 1.0          # in (0, 1]
    drift_db: object = 0.0            # added per-MZI insertion loss, >= 0
    tuning_factor: object = 1.0       # trimming power multiplier, >= 1
    name: str = "fault"

    def batch_shape(self) -> Tuple[int, ...]:
        return np.broadcast_shapes(
            *(np.shape(getattr(self, f)) for f in _SCENARIO_FIELDS))

    @property
    def n_scenarios(self) -> int:
        shape = self.batch_shape()
        return int(shape[0]) if shape else 1

    def is_healthy(self) -> bool:
        return (np.all(np.asarray(self.dead_lambda_frac) == 0)
                and np.all(np.asarray(self.failed_laser_banks) == 0)
                and np.all(np.asarray(self.failed_gateways) == 0)
                and np.all(np.asarray(self.wpe_factor) == 1)
                and np.all(np.asarray(self.drift_db) == 0)
                and np.all(np.asarray(self.tuning_factor) == 1))


HEALTHY = FaultScenario(name="healthy")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Failure *rates* (per-component probabilities / drift scales).  The
    reference counts (`n_*_ref`) anchor the absolute draws: a bank-failure
    probability of 0.1 over an 8-bank reference draws Binomial(8, 0.1) dead
    banks and applies that absolute count to every design — which is exactly
    what makes single-bank designs fragile and K-bank TRINE redundant."""

    p_lambda: float = 0.0        # per-wavelength (microring) death prob
    p_bank: float = 0.0          # per-laser-bank failure prob
    p_gateway: float = 0.0       # per-gateway-chiplet failure prob
    wpe_loss: float = 0.0        # mean fractional wall-plug-eff. degradation
    drift_sigma_db: float = 0.0  # thermal drift scale (dB per MZI)
    tuning_sigma: float = 0.0    # fractional trimming-power drift scale
    n_lambda_ref: int = 8
    n_banks_ref: int = 8
    n_gateways_ref: int = 32

    def scale(self, severity: float) -> "FaultModel":
        """Every rate scaled by `severity` (probabilities clipped to 1)."""
        s = float(severity)
        return dataclasses.replace(
            self,
            p_lambda=min(1.0, self.p_lambda * s),
            p_bank=min(1.0, self.p_bank * s),
            p_gateway=min(1.0, self.p_gateway * s),
            wpe_loss=min(0.95, self.wpe_loss * s),
            drift_sigma_db=self.drift_sigma_db * s,
            tuning_sigma=self.tuning_sigma * s,
        )

    def expected(self, name: Optional[str] = None) -> FaultScenario:
        """The deterministic mean scenario — what degradation curves sweep.
        Expected counts may be fractional (the survival algebra is
        continuous); drift uses the half-normal mean sigma*sqrt(2/pi)."""
        hn = math.sqrt(2.0 / math.pi)
        return FaultScenario(
            dead_lambda_frac=self.p_lambda,
            failed_laser_banks=self.p_bank * self.n_banks_ref,
            failed_gateways=self.p_gateway * self.n_gateways_ref,
            wpe_factor=max(0.05, 1.0 - self.wpe_loss),
            drift_db=self.drift_sigma_db * hn,
            tuning_factor=1.0 + self.tuning_sigma * hn,
            name=name or "expected",
        )

    def sample(self, n: int, rng=None,
               name: Optional[str] = None) -> FaultScenario:
        """Draw an (S=n, 1)-batched Monte-Carlo scenario."""
        rng = np.random.default_rng(rng)
        shp = (int(n), 1)
        dead = (rng.binomial(self.n_lambda_ref, min(1.0, self.p_lambda), shp)
                .astype(np.float64) / self.n_lambda_ref)
        banks = rng.binomial(self.n_banks_ref, min(1.0, self.p_bank),
                             shp).astype(np.float64)
        gws = rng.binomial(self.n_gateways_ref, min(1.0, self.p_gateway),
                           shp).astype(np.float64)
        wpe = np.clip(1.0 - rng.exponential(self.wpe_loss, shp), 0.05, 1.0)
        drift = np.abs(rng.normal(0.0, self.drift_sigma_db, shp))
        tuning = 1.0 + np.abs(rng.normal(0.0, self.tuning_sigma, shp))
        return FaultScenario(
            dead_lambda_frac=dead, failed_laser_banks=banks,
            failed_gateways=gws, wpe_factor=wpe, drift_db=drift,
            tuning_factor=tuning, name=name or f"mc{n}")


# --------------------------------------------------------------------------
# Columnar degradation
# --------------------------------------------------------------------------


def degrade_device_columns(cols: Mapping[str, np.ndarray],
                           scenario: FaultScenario,
                           xp=np) -> Dict[str, np.ndarray]:
    """Apply the input-side perturbations (drift, trimming, WPE) to a device
    column dict.  Batched scenario fields ((S, 1)) broadcast the perturbed
    columns to (S, N); untouched columns keep their shape and broadcast in
    the downstream kernels."""
    out = dict(cols)
    out["mzi.insertion_loss_db"] = (cols["mzi.insertion_loss_db"]
                                    + scenario.drift_db)
    out["mr.tuning_power_w"] = (cols["mr.tuning_power_w"]
                                * scenario.tuning_factor)
    out["laser.wall_plug_efficiency"] = (cols["laser.wall_plug_efficiency"]
                                         * scenario.wpe_factor)
    return out


def port_survival(scenario: FaultScenario, n_gateways=None, xp=np):
    """Surviving-port fraction for designs without subnetwork structure
    (buses, electrical mesh, metallic ICI): (G - failed) / G, clipped."""
    g = np.float64(NetworkParams().n_gateways) if n_gateways is None \
        else n_gateways
    return xp.clip((g - scenario.failed_gateways)
                   / xp.maximum(g, 1e-30), 0.0, 1.0)


def _degrade_fields(fields: Dict[str, np.ndarray],
                    n_gateways, scenario: FaultScenario,
                    topology: str, xp=np) -> Dict[str, np.ndarray]:
    """Post-kernel survival derating of one topology's MODEL_FIELDS.

    Dead hardware stays physically on the waveguide: worst-path loss, ring /
    MZI counts, and stage counts are untouched (trimming and laser sizing
    keep paying for the dead fraction — conservative and monotone).  Only
    the *usable* bandwidth, wavelength count, and bank count shrink.
    """
    lam = xp.clip(1.0 - scenario.dead_lambda_frac, 0.0, 1.0)
    banks = fields["n_laser_banks"]
    if topology == "trine":
        # a dead gateway severs the SWMR subnetwork (and its bank) behind it
        lost_banks = scenario.failed_laser_banks + scenario.failed_gateways
        port = 1.0
    else:
        lost_banks = scenario.failed_laser_banks
        port = port_survival(scenario, n_gateways, xp)
    bank = xp.clip((banks - lost_banks) / xp.maximum(banks, 1e-30), 0.0, 1.0)

    is_el = fields["is_electrical"] > 0
    surv = xp.where(is_el, port, lam * bank * port)
    out = dict(fields)
    out["aggregate_bw_bps"] = fields["aggregate_bw_bps"] * surv
    out["effective_bw_bps"] = fields["effective_bw_bps"] * surv
    out["n_wavelengths"] = xp.where(
        is_el, fields["n_wavelengths"], fields["n_wavelengths"] * lam * bank)
    out["n_laser_banks"] = xp.where(is_el, banks, banks * bank)
    return out


def degraded_network_columns(
    cols: Mapping[str, np.ndarray],
    topo_id: np.ndarray,
    topologies: Sequence[str],
    scenario: FaultScenario,
    xp=np,
) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Fault-aware mirror of the sweep engine's network-column builder:
    perturb the device columns, run each topology's kernel, derate the
    emitted fields by the survival factors.  Returns ``(net_fields,
    degraded_device_cols)``; with an (S, 1)-batched scenario the net fields
    come back (S, N)."""
    dcols = degrade_device_columns(cols, scenario, xp)
    topo_id = np.asarray(topo_id)
    n = int(topo_id.size)
    full = np.broadcast_shapes(scenario.batch_shape(), (n,))
    out = {f: np.zeros(full, np.float64) for f in MODEL_FIELDS}
    for ti, name in enumerate(topologies):
        mask = topo_id == ti
        if not mask.any():
            continue
        sub = {k: (np.asarray(v)[..., mask] if np.ndim(v) else v)
               for k, v in dcols.items()}
        fields = TOPOLOGY_ARRAYS[name](sub, xp)
        g = np.asarray(cols["n_gateways"])
        g_sub = g[..., mask] if np.ndim(g) else g
        fields = _degrade_fields(fields, g_sub, scenario, name, xp)
        for f in MODEL_FIELDS:
            out[f][..., mask] = fields[f]
    return out, dcols


@dataclasses.dataclass(frozen=True)
class FaultedColumns:
    """A scenario-carrying `columns_fn` hook for `sweep_chunked` /
    `pareto_search`: every chunk is evaluated under `scenario` instead of
    the healthy fabric.

    The streaming engine recognizes the ``scenario`` attribute and composes
    the degradation on-device — the six scenario fields become runtime
    inputs of its universal chunk program, so faulted sweeps keep the
    device-resident decode path and its prefetch pipeline.  Calling the
    hook directly runs the numpy reference path
    (`degraded_network_columns`), which is what legacy callers and the
    device-vs-host parity tests use."""

    scenario: FaultScenario
    xp: object = np

    def __call__(self, cols, topo_id, topologies):
        return degraded_network_columns(cols, topo_id, topologies,
                                        self.scenario, self.xp)


def faulted_columns_fn(scenario: FaultScenario, xp=np) -> FaultedColumns:
    """Build the fault hook for `sweep_chunked` / `pareto_search` (see
    `FaultedColumns`)."""
    return FaultedColumns(scenario, xp)


def evaluate_degraded(
    traffic: Traffic,
    scenario: FaultScenario,
    topology: str,
    params: Optional[NetworkParams] = None,
    devices=None,
    n_subnetworks: int = 0,
    active_fraction: float = 1.0,
) -> Dict[str, np.ndarray]:
    """Batch-of-one convenience: the full metric dict of one design point
    under `scenario`.  Metric shapes are (1,) for a scalar scenario and
    (S, 1) for a batch — a zero-bandwidth scenario yields inf latency /
    energy (the design is dead, not mis-modeled)."""
    cols = {k: np.atleast_1d(np.asarray(v, np.float64))
            for k, v in params_columns(params or NetworkParams(), devices,
                                       n_subnetworks).items()}
    topo_id = np.zeros(1, np.int64)
    nets, dcols = degraded_network_columns(cols, topo_id, (topology,),
                                           scenario)
    return evaluate_columns(nets, dcols, traffic.total_bits,
                            traffic.n_transfers, active_fraction)


# --------------------------------------------------------------------------
# Chunked Monte-Carlo availability (yield columns over a design grid)
# --------------------------------------------------------------------------


class AvailabilityReducer(ChunkReducer):
    """Per-design-point Monte-Carlo yield columns from an (S, chunk) metric
    stream: expected degraded EDP/EPB and availability P(EPB <= budget).

    Output arrays are O(grid) (three float64 columns — ~2.4 MB per 1e5
    points); the (S x chunk) intermediates stay bounded by the chunk size.
    `finish` also reports the expected-EDP argmin among points meeting the
    availability floor — the "best survivable design"."""

    def __init__(self, epb_budget_j: float, min_availability: float = 0.9):
        self.epb_budget_j = float(epb_budget_j)
        self.min_availability = float(min_availability)

    def init(self, spec: GridSpec):
        n = spec.n
        return {"expected_edp": np.zeros(n), "expected_epb": np.zeros(n),
                "availability": np.zeros(n), "n_scenarios": 0}

    def step(self, carry, chunk: SweepChunk):
        lat = np.atleast_2d(chunk.metrics["latency_s"])
        en = np.atleast_2d(chunk.metrics["energy_j"])
        epb = np.atleast_2d(chunk.metrics["energy_per_bit_j"])
        sl = slice(chunk.start, chunk.stop)
        with np.errstate(invalid="ignore", over="ignore"):
            carry["expected_edp"][sl] = np.mean(lat * en, axis=0)
        carry["expected_epb"][sl] = np.mean(epb, axis=0)
        carry["availability"][sl] = np.mean(epb <= self.epb_budget_j, axis=0)
        carry["n_scenarios"] = int(epb.shape[0])
        return carry

    def finish(self, carry, spec: GridSpec):
        avail = carry["availability"]
        edp = carry["expected_edp"]
        ok = avail >= self.min_availability
        best = None
        if ok.any():
            cand = np.where(ok, edp, np.inf)
            i = int(np.argmin(cand))
            best = {"index": i, "config": spec.config_at(i),
                    "expected_edp": float(edp[i]),
                    "availability": float(avail[i])}
        return dict(carry, n=spec.n, best_survivable=best,
                    epb_budget_j=self.epb_budget_j,
                    min_availability=self.min_availability)


def availability_search(
    traffic: Traffic,
    scenarios: FaultScenario,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices=None,
    epb_budget_j: float = 1e-9,
    min_availability: float = 0.9,
    chunk_size: int = 8192,
    materialize: str = "auto",
    prefetch: Optional[int] = None,
    **axes,
):
    """Chunked Monte-Carlo availability over a design grid: every chunk is
    evaluated under the (S, 1)-batched `scenarios`, and the reducer folds
    the scenario axis into per-point yield columns.  Peak memory is
    O(S * chunk_size) regardless of grid size.  `materialize` / `prefetch`
    pass through to `sweep_chunked` (device-resident decode + prefetch
    pipeline by default)."""
    return sweep_chunked(
        traffic, AvailabilityReducer(epb_budget_j, min_availability),
        topologies=topologies, devices=devices, chunk_size=chunk_size,
        columns_fn=faulted_columns_fn(scenarios),
        materialize=materialize, prefetch=prefetch, **axes)

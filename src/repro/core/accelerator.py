"""2.5D-CrossLight accelerator analytical model (paper Sec. V, Fig. 6).

Three variants, matching the paper's comparison:

  * CrossLight            — monolithic SiPh accelerator [16]: one reticle-
                            limited die, homogeneous MAC vector size, off-chip
                            DRAM bandwidth, long on-die shared photonic buses
                            (high loss -> high laser power).
  * 2.5D-CrossLight-Elec  — chiplet scale-out, electrical mesh interposer [21].
  * 2.5D-CrossLight-SiPh  — chiplet scale-out, TRINE-style photonic interposer
                            with PCMC-adaptive gateways.

Compute model: noncoherent broadcast-and-weight photonic MAC units.  A unit
with vector size V performs a V-long dot-product slice per cycle; a layer with
dot length L needs ceil(L/V) passes per dot product.  Heterogeneous chiplets
(different V per chiplet, e.g. 3x3-conv chiplets vs 7x7 vs FC) reduce the
pass count + wavelength-slot waste — one of the paper's two stated reasons
for the 2.5D win (the other being the high-bandwidth photonic interposer).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES
from repro.core.power import Traffic, evaluate_network, NetworkReport
from repro.core.topology import (
    NetworkModel,
    NetworkParams,
    sprint_bus,
    trine_network,
    electrical_mesh,
)
from repro.core.planner import plan_gateway_activation
from repro.core.workloads import Workload


@dataclasses.dataclass(frozen=True)
class ChipletSpec:
    n_units: int          # photonic MAC (VDP) units on this chiplet
    vector_size: int      # wavelengths per unit = dot-slice width


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    chiplets: List[ChipletSpec]
    network: NetworkModel
    mem_bw_bytes_per_s: float
    mac_rate_hz: float = 5e9          # VDP issue rate (MR-modulation limited)
    lambda_slot_energy_j: float = 30e-15  # per wavelength-slot MAC energy
    adaptive_gateways: bool = False    # PCMC bandwidth adaptation (SiPh 2.5D)
    transfers_per_layer: int = 16


@dataclasses.dataclass(frozen=True)
class AccelReport:
    name: str
    latency_s: float
    power_w: float
    energy_j: float
    epb_j: float                       # interposer-network energy per bit
    compute_s: float
    network_s: float
    memory_s: float
    network_energy_j: float


# --------------------------------------------------------------------------
# The paper's three configurations
# --------------------------------------------------------------------------

def monolithic_crosslight(d: Optional[DeviceLibrary] = None) -> AcceleratorConfig:
    """Monolithic CrossLight: homogeneous vec=32 units; one co-packaged DRAM
    stack (~50GB/s); on-die GLB<->unit traffic rides a long MWMR photonic bus
    spanning all 32 unit clusters (SPRINT-like loss profile on a big die --
    the accumulated ring/propagation loss on the monolithic die is exactly
    why the paper's 2.5D split wins on EPB)."""
    p = NetworkParams(n_gateways=32, n_mem_chiplets=1,
                      mem_bw_bytes_per_s=50e9, interposer_side_cm=2.0)
    net = sprint_bus(p, d)
    net = dataclasses.replace(net, name="CrossLight-onchip",
                              effective_bw_bps=min(net.effective_bw_bps, 50e9 * 8))
    return AcceleratorConfig(
        name="CrossLight",
        chiplets=[ChipletSpec(n_units=512, vector_size=32)],
        network=net,
        mem_bw_bytes_per_s=50e9,
    )


def _hetero_chiplets() -> List[ChipletSpec]:
    """Heterogeneous 2.5D chiplet mix (paper Fig. 5: 3x3-conv chiplets, 7x7
    chiplets, large FC chiplets)."""
    return [
        ChipletSpec(n_units=512, vector_size=9),     # 3x3 kernels
        ChipletSpec(n_units=512, vector_size=27),    # 3x3xC slices
        ChipletSpec(n_units=512, vector_size=49),    # 7x7 kernels
        ChipletSpec(n_units=512, vector_size=128),   # FC / pointwise
    ]


ACCEL_NETPARAMS = NetworkParams(n_gateways=64, n_mem_chiplets=4)


def crosslight_25d_siph(d: Optional[DeviceLibrary] = None,
                        params: Optional[NetworkParams] = None) -> AcceleratorConfig:
    p = params or ACCEL_NETPARAMS
    return AcceleratorConfig(
        name="2.5D-CrossLight-SiPh",
        chiplets=_hetero_chiplets(),
        network=trine_network(p, d=d),
        mem_bw_bytes_per_s=p.n_mem_chiplets * p.mem_bw_bytes_per_s,
        adaptive_gateways=True,
    )


def crosslight_25d_elec(d: Optional[DeviceLibrary] = None,
                        params: Optional[NetworkParams] = None) -> AcceleratorConfig:
    p = params or ACCEL_NETPARAMS
    return AcceleratorConfig(
        name="2.5D-CrossLight-Elec",
        chiplets=_hetero_chiplets(),
        network=electrical_mesh(p, d),
        mem_bw_bytes_per_s=p.n_mem_chiplets * p.mem_bw_bytes_per_s,
    )


# --------------------------------------------------------------------------
# Struct-of-arrays flattening (consumed by core.sweep's batched evaluator)
# --------------------------------------------------------------------------

def layer_columns(wl: Workload) -> Dict[str, np.ndarray]:
    """Workload layers as float64 columns, one row per layer."""
    def col(get):
        return np.asarray([get(l) for l in wl.layers], np.float64)

    return {
        "dot_length": col(lambda l: l.dot_length),
        "n_dots": col(lambda l: l.n_dots),
        "weight_bytes": col(lambda l: l.weight_bytes),
        "in_bytes": col(lambda l: l.in_bytes),
        "out_bytes": col(lambda l: l.out_bytes),
    }


def chiplet_columns(accel: AcceleratorConfig) -> Dict[str, np.ndarray]:
    """Chiplet mix as float64 columns, one row per chiplet."""
    return {
        "n_units": np.asarray([c.n_units for c in accel.chiplets], np.float64),
        "vector_size": np.asarray([c.vector_size for c in accel.chiplets], np.float64),
    }


# --------------------------------------------------------------------------
# Evaluation
# --------------------------------------------------------------------------

def _layer_compute(accel: AcceleratorConfig, dot_length: int, n_dots: float):
    """Layer split across all chiplets proportionally to their throughput for
    this dot length.  Returns (seconds, wavelength-slots consumed)."""
    total_thr = 0.0
    slots_per_dot_best = None
    for c in accel.chiplets:
        passes = -(-dot_length // c.vector_size)  # ceil
        thr = c.n_units * accel.mac_rate_hz / passes  # dots/s on this chiplet
        total_thr += thr
        slots = passes * c.vector_size
        if slots_per_dot_best is None or slots < slots_per_dot_best:
            slots_per_dot_best = slots
    secs = n_dots / total_thr
    # energy accounting uses the best-matching chiplet's slot count weighted
    # by throughput share; approximate with the best (mapping preference)
    return secs, n_dots * slots_per_dot_best


def evaluate_accelerator(
    accel: AcceleratorConfig,
    wl: Workload,
    devices: Optional[DeviceLibrary] = None,
) -> AccelReport:
    d = devices or DEFAULT_DEVICES
    total_lat = 0.0
    total_compute = total_net = total_mem = 0.0
    compute_energy = 0.0
    net_energy = 0.0
    total_bits = 0.0
    static_net_power_probe: Optional[NetworkReport] = None

    for layer in wl.layers:
        c_s, slots = _layer_compute(accel, layer.dot_length, layer.n_dots)
        compute_energy += slots * accel.lambda_slot_energy_j

        t = Traffic(bytes_read=layer.weight_bytes + layer.in_bytes,
                    bytes_written=layer.out_bytes,
                    n_transfers=accel.transfers_per_layer)
        frac = 1.0
        if accel.adaptive_gateways:
            demand = t.total_bytes / max(c_s, 1e-12)
            frac = plan_gateway_activation(
                demand, accel.network.effective_bw_bps / 8.0,
                n_gateways=max(1, accel.network.n_wavelengths // 8))
        rep = evaluate_network(accel.network, t, d, active_fraction=frac)
        mem_s = t.total_bytes / accel.mem_bw_bytes_per_s

        # double-buffered: network/memory overlap compute; layer pays the max
        total_lat += max(c_s, rep.latency_s, mem_s)
        total_compute += c_s
        total_net += rep.latency_s
        total_mem += mem_s
        net_energy += rep.energy_j
        total_bits += t.total_bits
        static_net_power_probe = rep

    energy = compute_energy + net_energy
    return AccelReport(
        name=accel.name,
        latency_s=total_lat,
        power_w=energy / max(total_lat, 1e-30),
        energy_j=energy,
        epb_j=net_energy / max(total_bits, 1.0),
        compute_s=total_compute,
        network_s=total_net,
        memory_s=total_mem,
        network_energy_j=net_energy,
    )

"""2.5D-CrossLight accelerator analytical model (paper Sec. V, Fig. 6).

Three variants, matching the paper's comparison:

  * CrossLight            — monolithic SiPh accelerator [16]: one reticle-
                            limited die, homogeneous MAC vector size, off-chip
                            DRAM bandwidth, long on-die shared photonic buses
                            (high loss -> high laser power).
  * 2.5D-CrossLight-Elec  — chiplet scale-out, electrical mesh interposer [21].
  * 2.5D-CrossLight-SiPh  — chiplet scale-out, TRINE-style photonic interposer
                            with PCMC-adaptive gateways.

Compute model: noncoherent broadcast-and-weight photonic MAC units.  A unit
with vector size V performs a V-long dot-product slice per cycle; a layer with
dot length L needs ceil(L/V) passes per dot product.  Heterogeneous chiplets
(different V per chiplet, e.g. 3x3-conv chiplets vs 7x7 vs FC) reduce the
pass count + wavelength-slot waste — one of the paper's two stated reasons
for the 2.5D win (the other being the high-bandwidth photonic interposer).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES, device_columns
from repro.core.power import (
    EVAL_DEVICE_FIELDS,
    Traffic,
    engine_x64,
    eval_network_math,
    evaluate_network,
)
from repro.core.topology import (
    MODEL_FIELDS,
    NetworkModel,
    NetworkParams,
    sprint_bus,
    trine_network,
    electrical_mesh,
)
from repro.core.planner import plan_gateway_activation, plan_gateway_activation_arr
from repro.core.workloads import Workload


@dataclasses.dataclass(frozen=True)
class ChipletSpec:
    n_units: int          # photonic MAC (VDP) units on this chiplet
    vector_size: int      # wavelengths per unit = dot-slice width


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    chiplets: List[ChipletSpec]
    network: NetworkModel
    mem_bw_bytes_per_s: float
    mac_rate_hz: float = 5e9          # VDP issue rate (MR-modulation limited)
    lambda_slot_energy_j: float = 30e-15  # per wavelength-slot MAC energy
    adaptive_gateways: bool = False    # PCMC bandwidth adaptation (SiPh 2.5D)
    transfers_per_layer: int = 16


# AccelReport metric fields, in emission order — the accelerator-side metric
# vocabulary (`core.search.refine_codesign` validates objectives against it)
ACCEL_REPORT_FIELDS = (
    "latency_s", "power_w", "energy_j", "epb_j",
    "compute_s", "network_s", "memory_s", "network_energy_j",
)


@dataclasses.dataclass(frozen=True)
class AccelReport:
    name: str
    latency_s: float
    power_w: float
    energy_j: float
    epb_j: float                       # interposer-network energy per bit
    compute_s: float
    network_s: float
    memory_s: float
    network_energy_j: float


# --------------------------------------------------------------------------
# The paper's three configurations
# --------------------------------------------------------------------------

def monolithic_crosslight(d: Optional[DeviceLibrary] = None) -> AcceleratorConfig:
    """Monolithic CrossLight: homogeneous vec=32 units; one co-packaged DRAM
    stack (~50GB/s); on-die GLB<->unit traffic rides a long MWMR photonic bus
    spanning all 32 unit clusters (SPRINT-like loss profile on a big die --
    the accumulated ring/propagation loss on the monolithic die is exactly
    why the paper's 2.5D split wins on EPB)."""
    p = NetworkParams(n_gateways=32, n_mem_chiplets=1,
                      mem_bw_bytes_per_s=50e9, interposer_side_cm=2.0)
    net = sprint_bus(p, d)
    net = dataclasses.replace(net, name="CrossLight-onchip",
                              effective_bw_bps=min(net.effective_bw_bps, 50e9 * 8))
    return AcceleratorConfig(
        name="CrossLight",
        chiplets=[ChipletSpec(n_units=512, vector_size=32)],
        network=net,
        mem_bw_bytes_per_s=50e9,
    )


def _hetero_chiplets() -> List[ChipletSpec]:
    """Heterogeneous 2.5D chiplet mix (paper Fig. 5: 3x3-conv chiplets, 7x7
    chiplets, large FC chiplets)."""
    return [
        ChipletSpec(n_units=512, vector_size=9),     # 3x3 kernels
        ChipletSpec(n_units=512, vector_size=27),    # 3x3xC slices
        ChipletSpec(n_units=512, vector_size=49),    # 7x7 kernels
        ChipletSpec(n_units=512, vector_size=128),   # FC / pointwise
    ]


ACCEL_NETPARAMS = NetworkParams(n_gateways=64, n_mem_chiplets=4)


def crosslight_25d_siph(d: Optional[DeviceLibrary] = None,
                        params: Optional[NetworkParams] = None) -> AcceleratorConfig:
    p = params or ACCEL_NETPARAMS
    return AcceleratorConfig(
        name="2.5D-CrossLight-SiPh",
        chiplets=_hetero_chiplets(),
        network=trine_network(p, d=d),
        mem_bw_bytes_per_s=p.n_mem_chiplets * p.mem_bw_bytes_per_s,
        adaptive_gateways=True,
    )


def crosslight_25d_elec(d: Optional[DeviceLibrary] = None,
                        params: Optional[NetworkParams] = None) -> AcceleratorConfig:
    p = params or ACCEL_NETPARAMS
    return AcceleratorConfig(
        name="2.5D-CrossLight-Elec",
        chiplets=_hetero_chiplets(),
        network=electrical_mesh(p, d),
        mem_bw_bytes_per_s=p.n_mem_chiplets * p.mem_bw_bytes_per_s,
    )


# --------------------------------------------------------------------------
# Struct-of-arrays flattening (consumed by core.sweep's batched evaluator)
# --------------------------------------------------------------------------

def layer_columns(wl: Workload) -> Dict[str, np.ndarray]:
    """Workload layers as float64 columns, one row per layer."""
    def col(get):
        return np.asarray([get(l) for l in wl.layers], np.float64)

    return {
        "dot_length": col(lambda l: l.dot_length),
        "n_dots": col(lambda l: l.n_dots),
        "weight_bytes": col(lambda l: l.weight_bytes),
        "in_bytes": col(lambda l: l.in_bytes),
        "out_bytes": col(lambda l: l.out_bytes),
    }


def chiplet_columns(accel: AcceleratorConfig) -> Dict[str, np.ndarray]:
    """Chiplet mix as float64 columns, one row per chiplet."""
    return {
        "n_units": np.asarray([c.n_units for c in accel.chiplets], np.float64),
        "vector_size": np.asarray([c.vector_size for c in accel.chiplets], np.float64),
    }


def chiplet_mix_columns(mixes: Sequence[Sequence[ChipletSpec]]
                        ) -> Dict[str, np.ndarray]:
    """A batch of chiplet mixes as (M, C) columns — the vmapped axis of the
    co-design grid kernel.  Shorter mixes are padded with zero-unit chiplets
    (vector_size 1), which the kernel masks out of both the throughput sum
    and the slot minimum."""
    if not mixes:
        raise ValueError("need at least one chiplet mix")
    width = max(len(m) for m in mixes)
    n_units = np.zeros((len(mixes), width), np.float64)
    vec = np.ones((len(mixes), width), np.float64)
    for i, mix in enumerate(mixes):
        for j, c in enumerate(mix):
            n_units[i, j] = c.n_units
            vec[i, j] = c.vector_size
    dead = np.where(~(n_units > 0).any(axis=1))[0]
    if dead.size:
        raise ValueError(
            f"chiplet mix(es) {dead.tolist()} have no active (n_units > 0) "
            "chiplets; an all-zero mix has no compute throughput")
    return {"n_units": n_units, "vector_size": vec}


# --------------------------------------------------------------------------
# Evaluation
# --------------------------------------------------------------------------

def _layer_compute(accel: AcceleratorConfig, dot_length: int, n_dots: float):
    """Layer split across all chiplets proportionally to their throughput for
    this dot length.  Returns (seconds, wavelength-slots consumed).

    Zero-unit chiplets (mix padding) carry no compute: they contribute
    neither throughput nor a slot count, exactly like the vmapped grid
    kernel's `units > 0` masks."""
    total_thr = 0.0
    slots_per_dot_best = None
    for c in accel.chiplets:
        if c.n_units <= 0:
            continue
        passes = -(-dot_length // c.vector_size)  # ceil
        thr = c.n_units * accel.mac_rate_hz / passes  # dots/s on this chiplet
        total_thr += thr
        slots = passes * c.vector_size
        if slots_per_dot_best is None or slots < slots_per_dot_best:
            slots_per_dot_best = slots
    if slots_per_dot_best is None:
        raise ValueError(
            f"accelerator {accel.name!r} has no active (n_units > 0) "
            "chiplets; an all-zero mix has no compute throughput")
    secs = n_dots / total_thr
    # energy accounting uses the best-matching chiplet's slot count weighted
    # by throughput share; approximate with the best (mapping preference)
    return secs, n_dots * slots_per_dot_best


def evaluate_accelerator(
    accel: AcceleratorConfig,
    wl: Workload,
    devices: Optional[DeviceLibrary] = None,
) -> AccelReport:
    d = devices or DEFAULT_DEVICES
    if not any(c.n_units > 0 for c in accel.chiplets):
        raise ValueError(
            f"accelerator {accel.name!r} has no active (n_units > 0) "
            "chiplets; an all-zero mix has no compute throughput")
    total_lat = 0.0
    total_compute = total_net = total_mem = 0.0
    compute_energy = 0.0
    net_energy = 0.0
    total_bits = 0.0

    for layer in wl.layers:
        c_s, slots = _layer_compute(accel, layer.dot_length, layer.n_dots)
        compute_energy += slots * accel.lambda_slot_energy_j

        t = Traffic(bytes_read=layer.weight_bytes + layer.in_bytes,
                    bytes_written=layer.out_bytes,
                    n_transfers=accel.transfers_per_layer)
        frac = 1.0
        if accel.adaptive_gateways:
            demand = t.total_bytes / max(c_s, 1e-12)
            frac = plan_gateway_activation(
                demand, accel.network.effective_bw_bps / 8.0,
                n_gateways=max(1, accel.network.n_wavelengths // 8))
        rep = evaluate_network(accel.network, t, d, active_fraction=frac)
        mem_s = t.total_bytes / accel.mem_bw_bytes_per_s

        # double-buffered: network/memory overlap compute; layer pays the max
        total_lat += max(c_s, rep.latency_s, mem_s)
        total_compute += c_s
        total_net += rep.latency_s
        total_mem += mem_s
        net_energy += rep.energy_j
        total_bits += t.total_bits

    energy = compute_energy + net_energy
    return AccelReport(
        name=accel.name,
        latency_s=total_lat,
        power_w=energy / max(total_lat, 1e-30),
        energy_j=energy,
        epb_j=net_energy / max(total_bits, 1.0),
        compute_s=total_compute,
        network_s=total_net,
        memory_s=total_mem,
        network_energy_j=net_energy,
    )


# --------------------------------------------------------------------------
# Co-design grid evaluation: vmapped chiplet-mix axis x network-config axis
# --------------------------------------------------------------------------


def _to_device(x) -> jax.Array:
    # float64 when jax_enable_x64 is on, namespace default otherwise; arrays
    # already on the device (the streaming engine's decoded chunk columns)
    # pass through untouched — no host round-trip on the hot path
    if isinstance(x, jax.Array):
        return x
    return jnp.asarray(np.asarray(x, np.float64))


def _bcast_col(v, n: int) -> jax.Array:
    """(n,) device column from a scalar/column that may already live on the
    device (kept there) or on the host (converted once)."""
    if isinstance(v, jax.Array):
        return jnp.broadcast_to(v, (n,))
    return jnp.asarray(np.broadcast_to(np.asarray(v, np.float64), (n,)))


def _accel_mix_math(cc, frac_ov, lc, nets, dev, mem_bw, mac_rate, slot_e,
                    xfers, *, adaptive: bool, relaxed: bool = False):
    """One chiplet mix against (N,) network configs and (L,) workload layers
    — pure jnp; `jax.vmap` lifts the mix axis, `jax.jit` compiles the result.

    cc   : (C,) chiplet columns (zero-unit rows are padding)
    lc   : (L,) layer columns
    nets : (N,) NetworkModel field columns
    dev  : (N,) EVAL_DEVICE_FIELDS columns
    frac_ov : optional precomputed PCMC activation, (L,) or (N, L); when
        None and `adaptive`, the planner runs in-kernel per (config, layer)
    returns (N,)-shaped AccelReport fields.

    With ``relaxed=True`` the pass count drops its ceil — ``max(L/V, 1)``
    instead of ``ceil(L/V)`` — so every accelerator axis (per-chiplet
    `n_units`/`vector_size` as positive reals, `mac_rate_hz`,
    `lambda_slot_energy_j`) carries a nonzero gradient: the continuous
    relaxation `core.search.refine_codesign` descends before snapping back
    to integers and re-scoring exactly (relaxed=False).  The two modes
    agree wherever V divides L and the relaxed pass count is >= 1; the
    zero-unit masks stay: padding rows are exact zeros, never descended.
    """
    vec = cc["vector_size"][:, None]                            # (C, 1)
    units = cc["n_units"][:, None]
    raw_passes = lc["dot_length"][None, :] / vec                # (C, L)
    passes = (jnp.maximum(raw_passes, 1.0) if relaxed
              else jnp.ceil(raw_passes))
    thr = jnp.where(units > 0, units * mac_rate / passes, 0.0)
    total_thr = thr.sum(0)                                      # (L,)
    slots = jnp.where(units > 0, passes * vec, jnp.inf).min(0)  # (L,)
    c_s = lc["n_dots"] / total_thr                              # (L,)
    compute_e = (lc["n_dots"] * slots).sum() * slot_e           # ()

    bytes_total = lc["weight_bytes"] + lc["in_bytes"] + lc["out_bytes"]
    bits = 8.0 * bytes_total                                    # (L,)
    if frac_ov is not None:
        frac = frac_ov
    elif adaptive:
        demand = bytes_total / jnp.maximum(c_s, 1e-12)          # (L,)
        n_gw = jnp.maximum(1.0, jnp.floor(nets["n_wavelengths"] / 8.0))
        frac = plan_gateway_activation_arr(
            demand[None, :], nets["effective_bw_bps"][:, None] / 8.0,
            n_gw[:, None], xp=jnp)                              # (N, L)
    else:
        frac = jnp.ones_like(bits)

    nets2 = {k: v[:, None] for k, v in nets.items()}            # (N, 1)
    dev2 = {k: v[:, None] for k, v in dev.items()}
    m = eval_network_math(nets2, dev2, bits[None, :], xfers, frac)  # (N, L)

    mem_s = bytes_total[None, :] / mem_bw[:, None]              # (N, L)
    # double-buffered: network/memory overlap compute; layer pays the max
    layer_lat = jnp.maximum(jnp.maximum(c_s[None, :], m["latency_s"]), mem_s)
    latency = layer_lat.sum(-1)                                 # (N,)
    net_e = m["energy_j"].sum(-1)
    net_s = m["latency_s"].sum(-1)
    energy = compute_e + net_e
    bits_sum = bits.sum()
    return {
        "latency_s": latency,
        "power_w": energy / jnp.maximum(latency, 1e-30),
        "energy_j": energy,
        "epb_j": net_e / jnp.maximum(bits_sum, 1.0),
        "compute_s": jnp.broadcast_to(c_s.sum(), latency.shape),
        "network_s": net_s,
        "memory_s": mem_s.sum(-1),
        "network_energy_j": net_e,
    }


@functools.lru_cache(maxsize=None)
def _grid_kernel(adaptive: bool, has_frac: bool):
    """Jitted vmap of `_accel_mix_math` over the chiplet-mix axis."""
    mix_axes = {"n_units": 0, "vector_size": 0}
    if has_frac:
        def single(cc, frac_ov, lc, nets, dev, mem_bw, mac_rate, slot_e,
                   xfers):
            return _accel_mix_math(cc, frac_ov, lc, nets, dev, mem_bw,
                                   mac_rate, slot_e, xfers, adaptive=adaptive)
        in_axes = (mix_axes, 0, None, None, None, None, None, None, None)
    else:
        def single(cc, lc, nets, dev, mem_bw, mac_rate, slot_e, xfers):
            return _accel_mix_math(cc, None, lc, nets, dev, mem_bw,
                                   mac_rate, slot_e, xfers, adaptive=adaptive)
        in_axes = (mix_axes, None, None, None, None, None, None, None)
    return jax.jit(jax.vmap(single, in_axes=in_axes))


def evaluate_accelerator_grid(
    wl: Workload,
    mixes: Sequence[Sequence[ChipletSpec]],
    nets: Mapping[str, np.ndarray],
    dev_cols: Mapping[str, np.ndarray],
    mem_bw_bytes_per_s,
    *,
    mac_rate_hz: float = 5e9,
    lambda_slot_energy_j: float = 30e-15,
    adaptive_gateways: bool = True,
    transfers_per_layer: int = 16,
    frac: Optional[np.ndarray] = None,
    as_numpy: bool = True,
) -> Dict[str, np.ndarray]:
    """Joint (chiplet-mix x network-config) accelerator evaluation in one
    jitted call: M mixes x N network configs x all L workload layers.

    `nets` holds MODEL_FIELDS columns and `dev_cols` EVAL_DEVICE_FIELDS
    columns, each (N,) or scalar (a sweep-chunk's `nets`/`cols` dicts fit
    directly); `mem_bw_bytes_per_s` likewise.  Columns that are already jax
    arrays stay on the device (zero host round-trips — the streaming
    co-design path feeds decoded chunks straight through).  Always evaluates
    in float64 (`power.engine_x64`), matching the sweep engine's fixed
    precision.  Returns (M, N) float64 arrays for every AccelReport field —
    numpy by default, device arrays with ``as_numpy=False`` (so a pipelined
    caller can defer the host sync to its fold point).  `frac` optionally
    overrides the in-kernel PCMC planner with a precomputed activation of
    shape (M, L) or (M, N, L) — `evaluate_accelerator_batch` uses that to
    keep its float64 host-side planner rounding.  Memory is O(M * N * L);
    stream big network grids in chunks (see `core.search.codesign_pareto`).
    """
    with engine_x64():
        lc = {k: _to_device(v) for k, v in layer_columns(wl).items()}
        cc = {k: _to_device(v) for k, v in chiplet_mix_columns(mixes).items()}
        shape = np.broadcast_shapes(
            *(np.shape(nets[k]) for k in MODEL_FIELDS),
            *(np.shape(dev_cols[k]) for k in EVAL_DEVICE_FIELDS),
            np.shape(mem_bw_bytes_per_s))
        n = int(shape[0]) if shape else 1
        nets_j = {k: _bcast_col(nets[k], n) for k in MODEL_FIELDS}
        dev_j = {k: _bcast_col(dev_cols[k], n) for k in EVAL_DEVICE_FIELDS}
        mem_bw_j = _bcast_col(mem_bw_bytes_per_s, n)
        mac = _to_device(mac_rate_hz)
        slot = _to_device(lambda_slot_energy_j)
        xfers = _to_device(transfers_per_layer)
        if frac is None:
            out = _grid_kernel(bool(adaptive_gateways), False)(
                cc, lc, nets_j, dev_j, mem_bw_j, mac, slot, xfers)
        else:
            out = _grid_kernel(bool(adaptive_gateways), True)(
                cc, _to_device(frac), lc, nets_j, dev_j, mem_bw_j, mac, slot,
                xfers)
        if not as_numpy:
            return out
        return {k: np.asarray(v, np.float64) for k, v in out.items()}


def evaluate_accelerator_batch(
    accel: AcceleratorConfig,
    wl: Workload,
    devices: Optional[DeviceLibrary] = None,
) -> AccelReport:
    """Batched mirror of `evaluate_accelerator`: the per-layer Python loop
    becomes one (M=1 mix, N=1 config) cell of the vmapped co-design grid
    kernel.  The PCMC gateway planner runs host-side in float64 so its step
    rounding is bit-identical to the scalar reference path."""
    d = devices or DEFAULT_DEVICES
    lc = layer_columns(wl)
    cc = chiplet_columns(accel)
    bytes_total = lc["weight_bytes"] + lc["in_bytes"] + lc["out_bytes"]
    net = accel.network
    if accel.adaptive_gateways:
        passes = np.ceil(lc["dot_length"][:, None] / cc["vector_size"][None, :])
        thr = cc["n_units"][None, :] * accel.mac_rate_hz / passes
        c_s = lc["n_dots"] / thr.sum(axis=1)
        demand = bytes_total / np.maximum(c_s, 1e-12)
        frac = plan_gateway_activation_arr(
            demand, net.effective_bw_bps / 8.0,
            max(1, net.n_wavelengths // 8))
    else:
        frac = np.ones_like(bytes_total)
    nets = {f: np.float64(getattr(net, f)) for f in MODEL_FIELDS}
    out = evaluate_accelerator_grid(
        wl, [accel.chiplets], nets, device_columns(d),
        accel.mem_bw_bytes_per_s,
        mac_rate_hz=accel.mac_rate_hz,
        lambda_slot_energy_j=accel.lambda_slot_energy_j,
        transfers_per_layer=accel.transfers_per_layer,
        frac=frac[None, :])
    return AccelReport(
        name=accel.name,
        **{f: float(out[f][0, 0])
           for f in ("latency_s", "power_w", "energy_j", "epb_j",
                     "compute_s", "network_s", "memory_s",
                     "network_energy_j")})

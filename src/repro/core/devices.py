"""Silicon-photonic device models for the TRINE / 2.5D-CrossLight analytical layer.

Every constant is a published device figure from the paper's own line of work
(TRINE [11], 2.5D-CrossLight [12], CrossLight [16], the survey [10]/[20]) or a
standard SiPh device-table value used by SPRINT/SPACX.  The analytical model in
`topology.py` / `power.py` composes these into loss chains -> laser power ->
energy, which is exactly the paper's evaluation methodology (there is no public
simulator for these works).

Units: losses in dB, powers in W, energies in J, rates in bit/s, lengths in cm.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# dB helpers (vectorized; numpy float64 — the analytical layer needs 64-bit
# precision for dB<->linear round-trips and uses no jax transforms, so it
# stays off the jax device entirely)
# ---------------------------------------------------------------------------


def db_to_linear(db):
    """Power ratio from dB."""
    return 10.0 ** (np.asarray(db, dtype=np.float64) / 10.0)


def linear_to_db(lin):
    return 10.0 * np.log10(np.asarray(lin, dtype=np.float64))


def dbm_to_watt(dbm):
    return 1e-3 * db_to_linear(dbm)


def watt_to_dbm(w):
    return linear_to_db(np.asarray(w, dtype=np.float64) / 1e-3)


# ---------------------------------------------------------------------------
# Device parameter records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MRParams:
    """Microring resonator (modulator / filter / weight bank element).

    through_loss_db : loss seen by a *non-resonant* wavelength passing the MR.
    drop_loss_db    : loss for the resonant wavelength coupled to the drop port.
    modulation_loss_db : excess loss when used as a modulator (OOK ER penalty).
    tuning_power_w  : static thermal trimming power to hold resonance
                      (process/thermal variation compensation).
    switching_energy_j : energy to retune resonance (weight update / switch).
    max_rate_bps    : modulation cutoff.
    resolution_bits : achievable amplitude-weight resolution when used as a
                      weight bank element (CrossLight's cross-layer design
                      demonstrates robust 16-level..256-level operation; we
                      default to 8 bits and sweep 4..8 in the ablation).
    """

    through_loss_db: float = 0.02     # [16] per-MR through loss
    drop_loss_db: float = 0.7         # [16] drop-port insertion loss
    modulation_loss_db: float = 0.7   # OOK modulator insertion/ER penalty
    tuning_power_w: float = 275e-6    # 0.275 mW/MR thermal trimming (survey [20])
    switching_energy_j: float = 20e-15
    max_rate_bps: float = 12e9        # paper Sec. IV: 12 GHz modulation
    resolution_bits: int = 8


@dataclasses.dataclass(frozen=True)
class MZIParams:
    """Broadband 2x2 Mach-Zehnder switch (TRINE's tree stages).

    insertion_loss_db : per-stage broadband insertion loss.
    switch_time_s     : carrier-injection (electro-optic) broadband MZI
                        switching time, ns-class.  Stage count still sets the
                        reconfiguration latency and the accumulated loss --
                        why TRINE's 2 stages beat Tree's 5.
    static_power_w    : bias/driver power per MZI while active.
    switch_energy_j   : energy per reconfiguration event.
    """

    insertion_loss_db: float = 1.0
    switch_time_s: float = 20e-9
    static_power_w: float = 1.0e-3
    switch_energy_j: float = 1.0e-9


@dataclasses.dataclass(frozen=True)
class PCMCParams:
    """Phase-change-material coupler (2.5D-CrossLight adaptive gateways).

    Non-volatile: holds state at zero static power; pays write energy to
    reconfigure. Used to (de)activate gateways for bandwidth adaptation.
    """

    insertion_loss_db: float = 0.3
    write_energy_j: float = 1.0e-9
    write_time_s: float = 10e-6
    static_power_w: float = 0.0


@dataclasses.dataclass(frozen=True)
class PhotodiodeParams:
    """Receiver: photodiode + TIA.

    sensitivity_dbm : minimum received optical power for target BER at
                      max_rate_bps (typ. -26 dBm @ ~12 GHz, Ge-on-Si PD).
    energy_per_bit_j: receiver-side (PD+TIA+SA) energy.
    """

    sensitivity_dbm: float = -26.0
    responsivity_a_per_w: float = 1.1
    energy_per_bit_j: float = 40e-15


@dataclasses.dataclass(frozen=True)
class LaserParams:
    """Off-chip comb / DFB laser bank.

    bank_overhead_w: fixed electrical overhead per laser bank (TEC, bias,
    driver) independent of emitted optical power.  This is why TRINE -- with
    one laser bank per subnetwork -- spends *more* laser power than SPACX or
    Tree (paper Sec. IV) even though its per-wavelength optical power is the
    lowest of all topologies.
    """

    wall_plug_efficiency: float = 0.10
    coupling_loss_db: float = 1.5     # fiber->chip coupler
    power_margin_db: float = 1.0      # link budget margin
    bank_overhead_w: float = 20e-3


@dataclasses.dataclass(frozen=True)
class WaveguideParams:
    propagation_loss_db_per_cm: float = 1.0   # interposer SiN/Si waveguide
    crossing_loss_db: float = 0.05
    splitter_loss_db: float = 0.13            # Y-branch excess loss
    bend_loss_db: float = 0.01
    group_velocity_cm_per_s: float = 7.5e9    # ~c/4 in Si waveguide


@dataclasses.dataclass(frozen=True)
class ModulatorDriverParams:
    """Electrical driver + SerDes at the writer gateway."""

    energy_per_bit_j: float = 60e-15
    serdes_energy_per_bit_j: float = 150e-15


@dataclasses.dataclass(frozen=True)
class ElectricalLinkParams:
    """Electrical interposer wire + mesh router baseline ([21], Sec. V).

    State-of-the-art electrical interposer wires: "hundreds of Gb/s with a
    few pJ/bit" (paper Sec. I); mesh routers add per-hop latency and energy.
    """

    energy_per_bit_j: float = 1.8e-12       # ~2 pJ/bit per hop (wire+router)
    router_latency_s: float = 2.5e-9        # pipelined router @ ~2GHz, 5 cyc
    wire_latency_s_per_cm: float = 160e-12  # RC-limited repeated wire
    link_bandwidth_bps: float = 32e9        # 32-bit @ 1 GHz interposer link
                                            # (cm-scale global wires; paper
                                            # Sec. I: dispersion/attenuation
                                            # caps electrical rates ~40Gb/s)
    router_power_w: float = 6e-3
    hotspot_saturation: float = 0.3         # mesh saturation throughput under
                                            # memory-hotspot (gather/scatter)
                                            # traffic, classic ~30% of ingress


@dataclasses.dataclass(frozen=True)
class DeviceLibrary:
    """One bag of device parameters threaded through the whole model."""

    mr: MRParams = MRParams()
    mzi: MZIParams = MZIParams()
    pcmc: PCMCParams = PCMCParams()
    pd: PhotodiodeParams = PhotodiodeParams()
    laser: LaserParams = LaserParams()
    wg: WaveguideParams = WaveguideParams()
    driver: ModulatorDriverParams = ModulatorDriverParams()
    elec: ElectricalLinkParams = ElectricalLinkParams()

    def replace(self, **kw) -> "DeviceLibrary":
        return dataclasses.replace(self, **kw)


DEFAULT_DEVICES = DeviceLibrary()


def device_columns(d: Optional[DeviceLibrary] = None) -> dict:
    """Flatten a DeviceLibrary to ``{"mr.through_loss_db": 0.02, ...}``.

    The dotted leaf names are the sweep engine's device-axis vocabulary: any
    of them can be turned into a grid dimension (`core.sweep.build_grid`),
    and `replace_device_leaves` maps a row of such columns back to a concrete
    DeviceLibrary for the scalar reference path.
    """
    d = d or DEFAULT_DEVICES
    cols = {}
    for group in dataclasses.fields(d):
        rec = getattr(d, group.name)
        for leaf in dataclasses.fields(rec):
            v = getattr(rec, leaf.name)
            if isinstance(v, (int, float)):
                cols[f"{group.name}.{leaf.name}"] = float(v)
    return cols


def replace_device_leaves(d: DeviceLibrary, leaves: dict) -> DeviceLibrary:
    """Rebuild a DeviceLibrary with dotted-name overrides applied."""
    by_group: dict = {}
    for dotted, value in leaves.items():
        group, leaf = dotted.split(".", 1)
        by_group.setdefault(group, {})[leaf] = value
    repl = {}
    for group, kv in by_group.items():
        rec = getattr(d, group)
        cast = {k: type(getattr(rec, k))(v) for k, v in kv.items()}
        repl[group] = dataclasses.replace(rec, **cast)
    return dataclasses.replace(d, **repl) if repl else d


def laser_electrical_power_w(
    path_loss_db,
    n_wavelengths,
    devices: Optional[DeviceLibrary] = None,
    n_banks: int = 1,
):
    """Laser wall-plug power needed so each of `n_wavelengths` arrives at the
    photodiode above sensitivity after `path_loss_db` of worst-case loss,
    plus the fixed per-bank overhead for `n_banks` laser banks.

    This is the paper's central energy argument: loss in dB adds per device
    passed, so required laser power grows *exponentially* (in linear units)
    with the number of on-path devices -- the reason bus topologies scale
    badly and stage-minimal trees (TRINE) win.
    """
    d = devices or DEFAULT_DEVICES
    p_rx_req_dbm = d.pd.sensitivity_dbm + d.laser.power_margin_db
    p_tx_dbm = p_rx_req_dbm + path_loss_db + d.laser.coupling_loss_db
    per_lambda_w = dbm_to_watt(p_tx_dbm)
    emitted = np.asarray(n_wavelengths, np.float64) * per_lambda_w / d.laser.wall_plug_efficiency
    return emitted + n_banks * d.laser.bank_overhead_w

"""Interposer network topologies: SPRINT/SPACX-style buses, Tree, TRINE, and
the electrical-mesh baseline ([21]).

Each topology reduces to a small set of quantities the power/latency models
consume:

  worst_path_loss_db   worst-case optical loss writer->reader (laser sizing)
  n_wavelengths        total active wavelengths (laser count)
  n_mr                 total microrings (trimming power)
  n_mzi                total MZI switches (static power, area)
  n_stages             switch stages on a path (reconfig latency, loss)
  aggregate_bw_bps     raw network bandwidth memory<->compute
  effective_bw_bps     after arbitration/contention derating (buses) --
                       switched trees are circuit-scheduled and keep raw BW
  per_transfer_s       fixed per-transfer overhead (arbitration or switching)

Geometry: gateways sit on an interposer of `interposer_side_cm`; bus
waveguides traverse the full perimeter, trees span half a side per stage.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    """Shared sizing for all topologies (paper Sec. IV evaluation setup)."""

    n_gateways: int = 32              # gateways on compute chiplets
    n_mem_chiplets: int = 1   # TRINE eval: one 100GB/s memory interface; 2.5D accel uses 4
    mem_bw_bytes_per_s: float = 100e9  # 100 GB/s per memory chiplet (microbump-limited)
    n_lambda: int = 8                 # WDM wavelengths per waveguide
    modulation_rate_bps: float = 12e9  # 12 GHz modulation
    gateway_rate_hz: float = 2e9      # 2 GHz gateway (serialization endpoint)
    gateway_width_bits: int = 64
    interposer_side_cm: float = 4.0


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    worst_path_loss_db: float
    n_wavelengths: int
    n_mr: int
    n_mzi: int
    n_stages: int
    aggregate_bw_bps: float
    effective_bw_bps: float
    per_transfer_s: float
    n_laser_banks: int = 1
    is_electrical: bool = False
    # electrical-only fields
    avg_hops: float = 0.0
    n_routers: int = 0


def _waveguide_bw(p: NetworkParams) -> float:
    """One waveguide carries n_lambda * modulation rate, but the endpoints can
    only source/sink at the gateway rate (the paper's 12 GHz modulator vs
    2 GHz gateway mismatch): a single gateway saturates at gw_rate*width."""
    return p.n_lambda * p.modulation_rate_bps


def _gateway_bw(p: NetworkParams) -> float:
    return p.gateway_rate_hz * p.gateway_width_bits


def _bus_contention_derate(writers_per_waveguide: int) -> float:
    """Shared-medium (MWMR) arbitration derating.  Token-slot arbitration
    wastes slots as the writer population grows; switched (circuit) networks
    do not pay this.  Calibrated so a 32-writer bus runs near ~40% utilization
    (SPRINT-class reported network utilizations)."""
    return 1.0 / (1.0 + 0.05 * max(0, writers_per_waveguide - 1))


def sprint_bus(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    """SPRINT [14]: MWMR bus -- every gateway's modulators+filters sit on every
    waveguide, so a signal's worst-case path passes (G-1) gateways' 2*n_lambda
    rings.  8 parallel waveguides to make aggregate BW comparable."""
    d = d or DEFAULT_DEVICES
    n_wg = 8
    g = p.n_gateways
    through = (g - 1) * 2 * p.n_lambda * d.mr.through_loss_db
    prop = 4 * p.interposer_side_cm * d.wg.propagation_loss_db_per_cm  # full perimeter
    loss = through + prop + d.mr.drop_loss_db + d.mr.modulation_loss_db
    raw = n_wg * _waveguide_bw(p)
    eff = raw * _bus_contention_derate(g)
    return NetworkModel(
        name="SPRINT",
        worst_path_loss_db=float(loss),
        n_wavelengths=n_wg * p.n_lambda,
        n_mr=(g + p.n_mem_chiplets) * 2 * p.n_lambda * 2,  # R+W sets on 2 waveguides each
        n_mzi=0,
        n_stages=0,
        aggregate_bw_bps=raw,
        effective_bw_bps=eff,
        per_transfer_s=12e-9,  # MWMR token arbitration
        n_laser_banks=n_wg,
    )


def spacx_bus(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    """SPACX [15]: wavelength/cluster-partitioned bus -- gateways are grouped
    into clusters of 8, each cluster on its own shorter waveguide segment, so
    fewer rings sit on any path (lower loss than SPRINT) at the cost of fewer
    concurrently-usable wavelengths (BW partitioned by cluster)."""
    d = d or DEFAULT_DEVICES
    cluster = 8
    n_clusters = p.n_gateways // cluster
    through = (cluster - 1) * 2 * p.n_lambda * d.mr.through_loss_db
    prop = 1.5 * p.interposer_side_cm * d.wg.propagation_loss_db_per_cm
    loss = through + prop + d.mr.drop_loss_db + d.mr.modulation_loss_db
    raw = n_clusters * _waveguide_bw(p)
    eff = raw * _bus_contention_derate(cluster)
    return NetworkModel(
        name="SPACX",
        worst_path_loss_db=float(loss),
        n_wavelengths=n_clusters * p.n_lambda,
        n_mr=p.n_gateways * 2 * p.n_lambda + p.n_mem_chiplets * 2 * p.n_lambda * n_clusters,
        n_mzi=0,
        n_stages=0,
        aggregate_bw_bps=raw,
        effective_bw_bps=eff,
        per_transfer_s=8e-9,
        n_laser_banks=n_clusters,
    )


def tree_network(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    """Single switched tree (paper Fig. 3b): all G gateways under one binary
    tree of broadband MZIs.  Stage count ceil(log2 G) (=5 for 32 gateways, as
    the paper states); memory BW restricted to ONE waveguide's bandwidth."""
    d = d or DEFAULT_DEVICES
    g = p.n_gateways
    stages = math.ceil(math.log2(g))
    prop = (p.interposer_side_cm / 2) * d.wg.propagation_loss_db_per_cm
    loss = stages * d.mzi.insertion_loss_db + prop + d.mr.drop_loss_db + d.mr.modulation_loss_db
    raw = _waveguide_bw(p)  # ONE waveguide -- the paper's stated limitation
    return NetworkModel(
        name="Tree",
        worst_path_loss_db=float(loss),
        n_wavelengths=p.n_lambda,
        n_mr=(g + p.n_mem_chiplets) * 2 * p.n_lambda,
        n_mzi=g - 1,
        n_stages=stages,
        aggregate_bw_bps=raw,
        effective_bw_bps=raw,
        per_transfer_s=stages * d.mzi.switch_time_s,
        n_laser_banks=1,
    )


def trine_network(
    p: NetworkParams,
    n_subnetworks: Optional[int] = None,
    d: Optional[DeviceLibrary] = None,
) -> NetworkModel:
    """TRINE [11] (paper Fig. 3c): K parallel tree subnetworks, each spanning
    G/K gateways => ceil(log2(G/K)) stages.  K chosen to match the memory
    bandwidth (planner.choose_subnetworks; =8 in the paper's setup).  With
    G=32, K=8: 4 gateways/subnet -> 2 stages (paper: "2 switch stages for
    TRINE, contrasting with 5 stages in the Tree")."""
    d = d or DEFAULT_DEVICES
    from repro.core.planner import choose_subnetworks  # cycle-free: planner imports params only

    k = n_subnetworks if n_subnetworks is not None else choose_subnetworks(p)
    g = p.n_gateways
    per = max(1, g // k)
    stages = max(1, math.ceil(math.log2(per)))
    prop = (p.interposer_side_cm / 3) * d.wg.propagation_loss_db_per_cm  # shorter subnet spans
    loss = stages * d.mzi.insertion_loss_db + prop + d.mr.drop_loss_db + d.mr.modulation_loss_db
    raw = k * _waveguide_bw(p)
    # memory can only source/sink at its aggregate BW (bandwidth matching)
    raw = min(raw, p.n_mem_chiplets * p.mem_bw_bytes_per_s * 8)
    return NetworkModel(
        name=f"TRINE-{k}",
        worst_path_loss_db=float(loss),
        # memory side needs one modulator/filter bank per subnetwork (SWMR) +
        # each gateway keeps one set (this is why TRINE's trimming power is
        # higher than SPACX/Tree -- more total rings)
        n_mr=(g + p.n_mem_chiplets * k) * 2 * p.n_lambda,
        n_wavelengths=k * p.n_lambda,
        n_mzi=k * (per - 1),
        n_stages=stages,
        aggregate_bw_bps=raw,
        effective_bw_bps=raw,
        per_transfer_s=stages * d.mzi.switch_time_s,
        n_laser_banks=k,
    )


def electrical_mesh(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    """Electrical 2D-mesh interposer NoC baseline (DeFT [21]), used by the
    2.5D-CrossLight-Elec-Interposer variant in Sec. V."""
    d = d or DEFAULT_DEVICES
    n = p.n_gateways + p.n_mem_chiplets
    side = math.ceil(math.sqrt(n))
    avg_hops = 2 * side / 3  # uniform-random average Manhattan distance
    hop_cm = p.interposer_side_cm / side
    per_hop_s = d.elec.router_latency_s + hop_cm * d.elec.wire_latency_s_per_cm
    bisection = side * d.elec.link_bandwidth_bps * 2
    # memory chiplets sit at the mesh edge with 2 usable ports each; hotspot
    # (gather/scatter to memory) saturates the mesh well below bisection
    mem_ingress = p.n_mem_chiplets * 2 * d.elec.link_bandwidth_bps
    raw = min(bisection, mem_ingress)
    eff = raw * d.elec.hotspot_saturation
    return NetworkModel(
        name="ElecMesh",
        worst_path_loss_db=0.0,
        n_wavelengths=0,
        n_mr=0,
        n_mzi=0,
        n_stages=int(2 * side),
        aggregate_bw_bps=raw,
        effective_bw_bps=eff,
        per_transfer_s=avg_hops * per_hop_s,
        is_electrical=True,
        avg_hops=avg_hops,
        n_routers=side * side,
    )


TOPOLOGIES = {
    "sprint": sprint_bus,
    "spacx": spacx_bus,
    "tree": tree_network,
    "trine": trine_network,
    "elec": electrical_mesh,
}

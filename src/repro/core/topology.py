"""Interposer network topologies: SPRINT/SPACX-style buses, Tree, TRINE, and
the electrical-mesh baseline ([21]).

Each topology reduces to a small set of quantities the power/latency models
consume:

  worst_path_loss_db   worst-case optical loss writer->reader (laser sizing)
  n_wavelengths        total active wavelengths (laser count)
  n_mr                 total microrings (trimming power)
  n_mzi                total MZI switches (static power, area)
  n_stages             switch stages on a path (reconfig latency, loss)
  aggregate_bw_bps     raw network bandwidth memory<->compute
  effective_bw_bps     after arbitration/contention derating (buses) --
                       switched trees are circuit-scheduled and keep raw BW
  per_transfer_s       fixed per-transfer overhead (arbitration or switching)

Geometry: gateways sit on an interposer of `interposer_side_cm`; bus
waveguides traverse the full perimeter, trees span half a side per stage.

Structure of this module (the vectorized sweep engine's foundation): every
topology is implemented once as a **columnar kernel** (`*_arrays`) that maps a
struct-of-arrays column dict — NetworkParams fields plus dotted DeviceLibrary
leaves, any of which may be a full grid axis — to struct-of-arrays
NetworkModel fields, elementwise in float64 numpy.  The scalar dataclass
constructors (`sprint_bus(p, d)` etc.) are thin batch-of-one wrappers kept for
existing callers; `core.sweep` drives the same kernels over 10k+ configs at
once.

Every columnar kernel takes an `xp` namespace argument (numpy by default,
`jax.numpy` for traced use): with `xp=jnp` the whole topology -> metrics chain
is differentiable in the continuous columns (losses, rates, bandwidths,
interposer geometry), which is what `core.search.refine_continuous` uses for
gradient-based local refinement of Pareto points.  Discrete quantities
(ceil/floor/round stage and subnetwork counts) are piecewise-constant and
contribute zero gradient, as intended.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional

import numpy as np

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES, device_columns
from repro.core.planner import ceil_log2, choose_subnetworks_arr


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    """Shared sizing for all topologies (paper Sec. IV evaluation setup)."""

    n_gateways: int = 32              # gateways on compute chiplets
    n_mem_chiplets: int = 1   # TRINE eval: one 100GB/s memory interface; 2.5D accel uses 4
    mem_bw_bytes_per_s: float = 100e9  # 100 GB/s per memory chiplet (microbump-limited)
    n_lambda: int = 8                 # WDM wavelengths per waveguide
    modulation_rate_bps: float = 12e9  # 12 GHz modulation
    gateway_rate_hz: float = 2e9      # 2 GHz gateway (serialization endpoint)
    gateway_width_bits: int = 64
    interposer_side_cm: float = 4.0


PARAM_FIELDS = tuple(f.name for f in dataclasses.fields(NetworkParams))

# NetworkModel numeric fields, in the order the columnar kernels emit them
MODEL_FIELDS = (
    "worst_path_loss_db", "n_wavelengths", "n_mr", "n_mzi", "n_stages",
    "aggregate_bw_bps", "effective_bw_bps", "per_transfer_s",
    "n_laser_banks", "is_electrical", "avg_hops", "n_routers",
)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    worst_path_loss_db: float
    n_wavelengths: int
    n_mr: int
    n_mzi: int
    n_stages: int
    aggregate_bw_bps: float
    effective_bw_bps: float
    per_transfer_s: float
    n_laser_banks: int = 1
    is_electrical: bool = False
    # electrical-only fields
    avg_hops: float = 0.0
    n_routers: int = 0


# --------------------------------------------------------------------------
# Columnar kernels (struct-of-arrays; elementwise float64)
# --------------------------------------------------------------------------

ColumnMap = Mapping[str, np.ndarray]


def _asx(xp, v):
    """Coerce to the kernel namespace: float64 for numpy (the analytical layer
    is 64-bit host math), namespace-default dtype for jax tracing."""
    return np.asarray(v, np.float64) if xp is np else xp.asarray(v)


def params_columns(p: NetworkParams, d: Optional[DeviceLibrary] = None,
                   n_subnetworks: int = 0) -> Dict[str, np.ndarray]:
    """Batch-of-one column dict for a scalar (params, devices) pair.

    `n_subnetworks` is the TRINE K override; 0 means "auto" (bandwidth-match
    via the planner), matching `trine_network(p, n_subnetworks=None)`.
    """
    cols = {name: np.float64(getattr(p, name)) for name in PARAM_FIELDS}
    for key, val in device_columns(d or DEFAULT_DEVICES).items():
        cols[key] = np.float64(val)
    cols["n_subnetworks"] = np.float64(n_subnetworks)
    return cols


def _fields(xp=np, **kw) -> Dict[str, np.ndarray]:
    """Assemble a MODEL_FIELDS dict, zero-filling the ones not given and
    broadcasting everything to a common shape."""
    out = {name: _asx(xp, kw.get(name, 0.0)) for name in MODEL_FIELDS}
    shape = np.broadcast_shapes(*(v.shape for v in out.values()))
    return {k: xp.broadcast_to(v, shape) for k, v in out.items()}


def _waveguide_bw_arr(c: ColumnMap):
    """One waveguide carries n_lambda * modulation rate, but the endpoints can
    only source/sink at the gateway rate (the paper's 12 GHz modulator vs
    2 GHz gateway mismatch): a single gateway saturates at gw_rate*width."""
    return c["n_lambda"] * c["modulation_rate_bps"]


def _bus_contention_derate_arr(writers_per_waveguide, xp=np):
    """Shared-medium (MWMR) arbitration derating.  Token-slot arbitration
    wastes slots as the writer population grows; switched (circuit) networks
    do not pay this.  Calibrated so a 32-writer bus runs near ~40% utilization
    (SPRINT-class reported network utilizations)."""
    return 1.0 / (1.0 + 0.05 * xp.maximum(0.0, writers_per_waveguide - 1.0))


def sprint_bus_arrays(c: ColumnMap, xp=np) -> Dict[str, np.ndarray]:
    """SPRINT [14]: MWMR bus -- every gateway's modulators+filters sit on every
    waveguide, so a signal's worst-case path passes (G-1) gateways' 2*n_lambda
    rings.  8 parallel waveguides to make aggregate BW comparable."""
    n_wg = 8.0
    g = c["n_gateways"]
    through = (g - 1) * 2 * c["n_lambda"] * c["mr.through_loss_db"]
    prop = 4 * c["interposer_side_cm"] * c["wg.propagation_loss_db_per_cm"]  # full perimeter
    loss = through + prop + c["mr.drop_loss_db"] + c["mr.modulation_loss_db"]
    raw = n_wg * _waveguide_bw_arr(c)
    return _fields(
        xp,
        worst_path_loss_db=loss,
        n_wavelengths=n_wg * c["n_lambda"],
        n_mr=(g + c["n_mem_chiplets"]) * 2 * c["n_lambda"] * 2,  # R+W sets on 2 waveguides each
        aggregate_bw_bps=raw,
        effective_bw_bps=raw * _bus_contention_derate_arr(g, xp),
        per_transfer_s=xp.full_like(loss, 12e-9),  # MWMR token arbitration
        n_laser_banks=xp.full_like(loss, n_wg),
    )


def spacx_bus_arrays(c: ColumnMap, xp=np) -> Dict[str, np.ndarray]:
    """SPACX [15]: wavelength/cluster-partitioned bus -- gateways are grouped
    into clusters of 8, each cluster on its own shorter waveguide segment, so
    fewer rings sit on any path (lower loss than SPRINT) at the cost of fewer
    concurrently-usable wavelengths (BW partitioned by cluster)."""
    cluster = 8.0
    if xp is np and np.any(np.asarray(c["n_gateways"]) < cluster):
        # data-dependent validation only on the concrete (numpy) path; under
        # jax tracing the caller is responsible for a valid grid
        raise ValueError("SPACX requires n_gateways >= 8 (one full cluster); "
                         "smaller values would leave zero usable waveguides")
    n_clusters = xp.floor(c["n_gateways"] / cluster)
    through = (cluster - 1) * 2 * c["n_lambda"] * c["mr.through_loss_db"]
    prop = 1.5 * c["interposer_side_cm"] * c["wg.propagation_loss_db_per_cm"]
    loss = through + prop + c["mr.drop_loss_db"] + c["mr.modulation_loss_db"]
    raw = n_clusters * _waveguide_bw_arr(c)
    return _fields(
        xp,
        worst_path_loss_db=loss,
        n_wavelengths=n_clusters * c["n_lambda"],
        n_mr=(c["n_gateways"] * 2 * c["n_lambda"]
              + c["n_mem_chiplets"] * 2 * c["n_lambda"] * n_clusters),
        aggregate_bw_bps=raw,
        effective_bw_bps=raw * _bus_contention_derate_arr(
            xp.full_like(loss, cluster), xp),
        per_transfer_s=xp.full_like(loss, 8e-9),
        n_laser_banks=n_clusters,
    )


def tree_network_arrays(c: ColumnMap, xp=np) -> Dict[str, np.ndarray]:
    """Single switched tree (paper Fig. 3b): all G gateways under one binary
    tree of broadband MZIs.  Stage count ceil(log2 G) (=5 for 32 gateways, as
    the paper states); memory BW restricted to ONE waveguide's bandwidth."""
    g = c["n_gateways"]
    # exact stage count: XLA's ceil(log2(.)) can overshoot at powers of two
    stages = ceil_log2(g, xp)
    prop = (c["interposer_side_cm"] / 2) * c["wg.propagation_loss_db_per_cm"]
    loss = (stages * c["mzi.insertion_loss_db"] + prop
            + c["mr.drop_loss_db"] + c["mr.modulation_loss_db"])
    raw = _waveguide_bw_arr(c)  # ONE waveguide -- the paper's stated limitation
    return _fields(
        xp,
        worst_path_loss_db=loss,
        n_wavelengths=c["n_lambda"],
        n_mr=(g + c["n_mem_chiplets"]) * 2 * c["n_lambda"],
        n_mzi=g - 1,
        n_stages=stages,
        aggregate_bw_bps=raw,
        effective_bw_bps=raw,
        per_transfer_s=stages * c["mzi.switch_time_s"],
        n_laser_banks=xp.ones_like(loss),
    )


def trine_network_arrays(c: ColumnMap, xp=np) -> Dict[str, np.ndarray]:
    """TRINE [11] (paper Fig. 3c): K parallel tree subnetworks, each spanning
    G/K gateways => ceil(log2(G/K)) stages.  K chosen to match the memory
    bandwidth (planner.choose_subnetworks; =8 in the paper's setup), unless
    the "n_subnetworks" column overrides it (>0).  With G=32, K=8:
    4 gateways/subnet -> 2 stages (paper: "2 switch stages for TRINE,
    contrasting with 5 stages in the Tree")."""
    g = c["n_gateways"]
    k_auto = choose_subnetworks_arr(
        c["n_lambda"], c["modulation_rate_bps"], c["n_mem_chiplets"],
        c["mem_bw_bytes_per_s"], g, xp=xp)
    k_over = _asx(xp, c.get("n_subnetworks", 0.0))
    k = xp.where(k_over > 0, k_over, k_auto)
    per = xp.maximum(1.0, xp.floor(g / k))
    stages = xp.maximum(1.0, ceil_log2(per, xp))
    prop = (c["interposer_side_cm"] / 3) * c["wg.propagation_loss_db_per_cm"]  # shorter subnet spans
    loss = (stages * c["mzi.insertion_loss_db"] + prop
            + c["mr.drop_loss_db"] + c["mr.modulation_loss_db"])
    raw = k * _waveguide_bw_arr(c)
    # memory can only source/sink at its aggregate BW (bandwidth matching)
    raw = xp.minimum(raw, c["n_mem_chiplets"] * c["mem_bw_bytes_per_s"] * 8)
    return _fields(
        xp,
        worst_path_loss_db=loss,
        # memory side needs one modulator/filter bank per subnetwork (SWMR) +
        # each gateway keeps one set (this is why TRINE's trimming power is
        # higher than SPACX/Tree -- more total rings)
        n_mr=(g + c["n_mem_chiplets"] * k) * 2 * c["n_lambda"],
        n_wavelengths=k * c["n_lambda"],
        n_mzi=k * (per - 1),
        n_stages=stages,
        aggregate_bw_bps=raw,
        effective_bw_bps=raw,
        per_transfer_s=stages * c["mzi.switch_time_s"],
        n_laser_banks=k,
    )


def electrical_mesh_arrays(c: ColumnMap, xp=np) -> Dict[str, np.ndarray]:
    """Electrical 2D-mesh interposer NoC baseline (DeFT [21]), used by the
    2.5D-CrossLight-Elec-Interposer variant in Sec. V."""
    n = c["n_gateways"] + c["n_mem_chiplets"]
    side = xp.ceil(xp.sqrt(n))
    avg_hops = 2 * side / 3  # uniform-random average Manhattan distance
    hop_cm = c["interposer_side_cm"] / side
    per_hop_s = (c["elec.router_latency_s"]
                 + hop_cm * c["elec.wire_latency_s_per_cm"])
    bisection = side * c["elec.link_bandwidth_bps"] * 2
    # memory chiplets sit at the mesh edge with 2 usable ports each; hotspot
    # (gather/scatter to memory) saturates the mesh well below bisection
    mem_ingress = c["n_mem_chiplets"] * 2 * c["elec.link_bandwidth_bps"]
    raw = xp.minimum(bisection, mem_ingress)
    return _fields(
        xp,
        aggregate_bw_bps=raw,
        effective_bw_bps=raw * c["elec.hotspot_saturation"],
        n_stages=2 * side,
        per_transfer_s=avg_hops * per_hop_s,
        n_laser_banks=xp.ones_like(side),  # dataclass default; unused for elec
        is_electrical=xp.ones_like(side),
        avg_hops=avg_hops,
        n_routers=side * side,
    )


TOPOLOGY_ARRAYS: Dict[str, Callable[[ColumnMap], Dict[str, np.ndarray]]] = {
    "sprint": sprint_bus_arrays,
    "spacx": spacx_bus_arrays,
    "tree": tree_network_arrays,
    "trine": trine_network_arrays,
    "elec": electrical_mesh_arrays,
}


# --------------------------------------------------------------------------
# Scalar wrappers (batch-of-one over the columnar kernels)
# --------------------------------------------------------------------------


def model_from_row(f: Mapping[str, np.ndarray], name: str,
                   i=()) -> NetworkModel:
    """One NetworkModel dataclass from row `i` of struct-of-arrays fields."""
    def _f(key):
        return float(np.asarray(f[key], np.float64)[i])

    return NetworkModel(
        name=name,
        worst_path_loss_db=_f("worst_path_loss_db"),
        n_wavelengths=int(_f("n_wavelengths")),
        n_mr=int(_f("n_mr")),
        n_mzi=int(_f("n_mzi")),
        n_stages=int(_f("n_stages")),
        aggregate_bw_bps=_f("aggregate_bw_bps"),
        effective_bw_bps=_f("effective_bw_bps"),
        per_transfer_s=_f("per_transfer_s"),
        n_laser_banks=int(_f("n_laser_banks")),
        is_electrical=bool(_f("is_electrical")),
        avg_hops=_f("avg_hops"),
        n_routers=int(_f("n_routers")),
    )


def sprint_bus(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    return model_from_row(sprint_bus_arrays(params_columns(p, d)), "SPRINT")


def spacx_bus(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    return model_from_row(spacx_bus_arrays(params_columns(p, d)), "SPACX")


def tree_network(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    return model_from_row(tree_network_arrays(params_columns(p, d)), "Tree")


def trine_network(
    p: NetworkParams,
    n_subnetworks: Optional[int] = None,
    d: Optional[DeviceLibrary] = None,
) -> NetworkModel:
    cols = params_columns(p, d, n_subnetworks=n_subnetworks or 0)
    f = trine_network_arrays(cols)
    k = int(float(np.asarray(f["n_laser_banks"], np.float64)))
    return model_from_row(f, f"TRINE-{k}")


def electrical_mesh(p: NetworkParams, d: Optional[DeviceLibrary] = None) -> NetworkModel:
    return model_from_row(electrical_mesh_arrays(params_columns(p, d)), "ElecMesh")


TOPOLOGIES = {
    "sprint": sprint_bus,
    "spacx": spacx_bus,
    "tree": tree_network,
    "trine": trine_network,
    "elec": electrical_mesh,
}

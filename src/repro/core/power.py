"""Network power / latency / energy evaluation (paper Fig. 4 methodology).

Given a `NetworkModel` (topology) and a traffic summary (bytes moved, number
of transfers), produce the three quantities the paper reports: network power
(W), total network latency (s), and energy (J) — plus energy-per-bit.

Power breakdown (photonic):
  laser     — sized by worst-case path loss (exponential in dB loss; the
              paper's core argument for stage-minimal topologies)
  trimming  — static thermal tuning, ∝ total MR count (TRINE pays more here
              than SPACX/Tree; paper acknowledges this)
  switch    — MZI bias/driver static power
  dynamic   — modulator driver + SerDes + receiver energy per bit

Electrical: per-bit link+router energy, router static power.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES, laser_electrical_power_w
from repro.core.topology import NetworkModel


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Aggregate interposer traffic of one workload (from workloads.py)."""

    bytes_read: float       # memory -> compute (SWMR)
    bytes_written: float    # compute -> memory (SWSR)
    n_transfers: int        # distinct layer-level transfer events

    @property
    def total_bytes(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def total_bits(self) -> float:
        return 8.0 * self.total_bytes


@dataclasses.dataclass(frozen=True)
class NetworkReport:
    name: str
    power_w: float          # static + average dynamic power
    latency_s: float
    energy_j: float
    energy_per_bit_j: float
    laser_power_w: float
    trimming_power_w: float


def evaluate_network(
    net: NetworkModel,
    traffic: Traffic,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
) -> NetworkReport:
    """Evaluate one topology under one workload's traffic.

    `active_fraction` models 2.5D-CrossLight's PCMC gateway adaptation: only
    that fraction of wavelengths/gateways is lit (laser + trimming scale
    down); bandwidth scales with it too.
    """
    d = devices or DEFAULT_DEVICES

    if net.is_electrical:
        # latency: serialization at effective BW + per-transfer hop latency
        ser = traffic.total_bits / net.effective_bw_bps
        lat = ser + traffic.n_transfers * net.per_transfer_s
        dyn_e = traffic.total_bits * d.elec.energy_per_bit_j * net.avg_hops
        static_p = net.n_routers * d.elec.router_power_w
        energy = dyn_e + static_p * lat
        return NetworkReport(
            name=net.name,
            power_w=float(static_p + dyn_e / max(lat, 1e-30)),
            latency_s=float(lat),
            energy_j=float(energy),
            energy_per_bit_j=float(energy / max(traffic.total_bits, 1.0)),
            laser_power_w=0.0,
            trimming_power_w=0.0,
        )

    frac = float(np.clip(active_fraction, 1e-3, 1.0))
    n_lambda_active = max(1, int(round(net.n_wavelengths * frac)))

    n_banks_active = max(1, int(round(net.n_laser_banks * frac)))
    laser_p = float(
        laser_electrical_power_w(
            net.worst_path_loss_db, n_lambda_active, d, n_banks=n_banks_active
        )
    )
    trimming_p = net.n_mr * d.mr.tuning_power_w * frac
    switch_p = net.n_mzi * d.mzi.static_power_w * frac
    static_p = laser_p + trimming_p + switch_p

    bw = net.effective_bw_bps * frac
    ser = traffic.total_bits / bw
    lat = ser + traffic.n_transfers * net.per_transfer_s

    per_bit = (
        d.driver.energy_per_bit_j
        + d.driver.serdes_energy_per_bit_j
        + d.pd.energy_per_bit_j
    )
    dyn_e = traffic.total_bits * per_bit
    switch_e = traffic.n_transfers * net.n_stages * d.mzi.switch_energy_j
    energy = static_p * lat + dyn_e + switch_e

    return NetworkReport(
        name=net.name,
        power_w=float(static_p + (dyn_e + switch_e) / max(lat, 1e-30)),
        latency_s=float(lat),
        energy_j=float(energy),
        energy_per_bit_j=float(energy / max(traffic.total_bits, 1.0)),
        laser_power_w=laser_p,
        trimming_power_w=float(trimming_p),
    )

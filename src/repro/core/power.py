"""Network power / latency / energy evaluation (paper Fig. 4 methodology).

Given a `NetworkModel` (topology) and a traffic summary (bytes moved, number
of transfers), produce the three quantities the paper reports: network power
(W), total network latency (s), and energy (J) — plus energy-per-bit.

Power breakdown (photonic):
  laser     — sized by worst-case path loss (exponential in dB loss; the
              paper's core argument for stage-minimal topologies)
  trimming  — static thermal tuning, ∝ total MR count (TRINE pays more here
              than SPACX/Tree; paper acknowledges this)
  switch    — MZI bias/driver static power
  dynamic   — modulator driver + SerDes + receiver energy per bit

Electrical: per-bit link+router energy, router static power.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from jax.experimental import enable_x64 as _enable_x64

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES, laser_electrical_power_w
from repro.core.topology import NetworkModel


def engine_x64():
    """Context manager forcing float64 tracing AND execution for the
    analytical engine's jitted programs, regardless of the session-wide
    ``jax_enable_x64`` setting.

    The streaming sweep/search engine promises bit-identical folds across
    execution modes (host-materialized vs device-decoded, serial vs
    pipelined, monolithic vs chunked).  That promise only holds if every
    engine program is traced and executed at one fixed precision: float32
    would additionally put discrete planner decisions (TRINE's K*, stage
    counts) one rounding error away from flipping between grid rows.  The
    flag is thread-local, so pipeline worker threads must enter their own
    context — `core.sweep` does this at every fold/enqueue site."""
    return _enable_x64()

# metric columns `eval_network_math` emits == NetworkReport fields — the
# network-side metric vocabulary.  `core.sweep.METRIC_FIELDS` aliases this,
# and `core.search.refine_continuous` validates objective names against it.
EVAL_METRIC_FIELDS = ("power_w", "latency_s", "energy_j", "energy_per_bit_j",
                      "laser_power_w", "trimming_power_w")

# device leaves the batched metric kernel reads (the topology kernels consume
# the rest); `eval_network_math` expects exactly these keys in its `dev` dict
EVAL_DEVICE_FIELDS = (
    "pd.sensitivity_dbm", "pd.energy_per_bit_j",
    "laser.power_margin_db", "laser.coupling_loss_db",
    "laser.wall_plug_efficiency", "laser.bank_overhead_w",
    "mr.tuning_power_w",
    "mzi.static_power_w", "mzi.switch_energy_j",
    "driver.energy_per_bit_j", "driver.serdes_energy_per_bit_j",
    "elec.energy_per_bit_j", "elec.router_power_w",
)


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Aggregate interposer traffic of one workload (from workloads.py)."""

    bytes_read: float       # memory -> compute (SWMR)
    bytes_written: float    # compute -> memory (SWSR)
    n_transfers: int        # distinct layer-level transfer events

    @property
    def total_bytes(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def total_bits(self) -> float:
        return 8.0 * self.total_bytes


@dataclasses.dataclass(frozen=True)
class NetworkReport:
    name: str
    power_w: float          # static + average dynamic power
    latency_s: float
    energy_j: float
    energy_per_bit_j: float
    laser_power_w: float
    trimming_power_w: float


def eval_network_math(nets: Dict[str, jax.Array], dev: Dict[str, jax.Array],
                      total_bits: jax.Array, n_transfers: jax.Array,
                      active_fraction: jax.Array) -> Dict[str, jax.Array]:
    """Branch-free batched mirror of `evaluate_network` in pure jax.numpy:
    both the photonic and the electrical formula evaluate on every lane and
    `is_electrical` selects.  All operands broadcast elementwise, so callers
    batch over configurations, workload traffics, per-layer traffic, or any
    combination.  Pure (un-jitted) so it composes: `core.sweep` jits it as
    the grid kernel (optionally with buffer donation), `core.accelerator`
    inlines it per (chiplet-mix, network, layer) lane, and
    `core.search.refine_continuous` differentiates through it (the round()
    wavelength/bank quantization is piecewise-constant — zero gradient)."""
    # ---- photonic ----
    frac = jnp.clip(active_fraction, 1e-3, 1.0)
    n_lambda_active = jnp.maximum(1.0, jnp.round(nets["n_wavelengths"] * frac))
    n_banks_active = jnp.maximum(1.0, jnp.round(nets["n_laser_banks"] * frac))
    p_tx_dbm = (dev["pd.sensitivity_dbm"] + dev["laser.power_margin_db"]
                + nets["worst_path_loss_db"] + dev["laser.coupling_loss_db"])
    per_lambda_w = 1e-3 * 10.0 ** (p_tx_dbm / 10.0)
    laser_p = (n_lambda_active * per_lambda_w / dev["laser.wall_plug_efficiency"]
               + n_banks_active * dev["laser.bank_overhead_w"])
    trimming_p = nets["n_mr"] * dev["mr.tuning_power_w"] * frac
    switch_p = nets["n_mzi"] * dev["mzi.static_power_w"] * frac
    static_p = laser_p + trimming_p + switch_p

    bw = nets["effective_bw_bps"] * frac
    lat_ph = total_bits / bw + n_transfers * nets["per_transfer_s"]
    per_bit = (dev["driver.energy_per_bit_j"]
               + dev["driver.serdes_energy_per_bit_j"]
               + dev["pd.energy_per_bit_j"])
    dyn_e = total_bits * per_bit
    switch_e = n_transfers * nets["n_stages"] * dev["mzi.switch_energy_j"]
    energy_ph = static_p * lat_ph + dyn_e + switch_e
    power_ph = static_p + (dyn_e + switch_e) / jnp.maximum(lat_ph, 1e-30)

    # ---- electrical ----
    lat_el = (total_bits / nets["effective_bw_bps"]
              + n_transfers * nets["per_transfer_s"])
    dyn_el = total_bits * dev["elec.energy_per_bit_j"] * nets["avg_hops"]
    static_el = nets["n_routers"] * dev["elec.router_power_w"]
    energy_el = dyn_el + static_el * lat_el
    power_el = static_el + dyn_el / jnp.maximum(lat_el, 1e-30)

    is_el = nets["is_electrical"] > 0
    latency = jnp.where(is_el, lat_el, lat_ph)
    energy = jnp.where(is_el, energy_el, energy_ph)
    return {
        "power_w": jnp.where(is_el, power_el, power_ph),
        "latency_s": latency,
        "energy_j": energy,
        "energy_per_bit_j": energy / jnp.maximum(total_bits, 1.0),
        "laser_power_w": jnp.where(is_el, 0.0, laser_p),
        "trimming_power_w": jnp.where(is_el, 0.0, trimming_p),
    }


def broadcast_metrics(out: Dict[str, jax.Array], xp=jnp) -> Dict[str, jax.Array]:
    """Broadcast every metric column to the common (traffic x scenario x
    config) result shape.  `eval_network_math` leaves each metric at its
    natural broadcast shape (a workload-independent column stays (N,)); the
    streaming engine needs uniform shapes so padded lanes slice off with one
    ``[..., :valid]`` — this helper is shared by the traced chunk program and
    the host-side `core.sweep.evaluate_columns` so both pad identically."""
    shape = np.broadcast_shapes(*(np.shape(v) for v in out.values()))
    return {k: xp.broadcast_to(v, shape) for k, v in out.items()}


def evaluate_network(
    net: NetworkModel,
    traffic: Traffic,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
) -> NetworkReport:
    """Evaluate one topology under one workload's traffic.

    `active_fraction` models 2.5D-CrossLight's PCMC gateway adaptation: only
    that fraction of wavelengths/gateways is lit (laser + trimming scale
    down); bandwidth scales with it too.
    """
    d = devices or DEFAULT_DEVICES

    if net.is_electrical:
        # latency: serialization at effective BW + per-transfer hop latency
        ser = traffic.total_bits / net.effective_bw_bps
        lat = ser + traffic.n_transfers * net.per_transfer_s
        dyn_e = traffic.total_bits * d.elec.energy_per_bit_j * net.avg_hops
        static_p = net.n_routers * d.elec.router_power_w
        energy = dyn_e + static_p * lat
        return NetworkReport(
            name=net.name,
            power_w=float(static_p + dyn_e / max(lat, 1e-30)),
            latency_s=float(lat),
            energy_j=float(energy),
            energy_per_bit_j=float(energy / max(traffic.total_bits, 1.0)),
            laser_power_w=0.0,
            trimming_power_w=0.0,
        )

    frac = float(np.clip(active_fraction, 1e-3, 1.0))
    n_lambda_active = max(1, int(round(net.n_wavelengths * frac)))

    n_banks_active = max(1, int(round(net.n_laser_banks * frac)))
    laser_p = float(
        laser_electrical_power_w(
            net.worst_path_loss_db, n_lambda_active, d, n_banks=n_banks_active
        )
    )
    trimming_p = net.n_mr * d.mr.tuning_power_w * frac
    switch_p = net.n_mzi * d.mzi.static_power_w * frac
    static_p = laser_p + trimming_p + switch_p

    bw = net.effective_bw_bps * frac
    ser = traffic.total_bits / bw
    lat = ser + traffic.n_transfers * net.per_transfer_s

    per_bit = (
        d.driver.energy_per_bit_j
        + d.driver.serdes_energy_per_bit_j
        + d.pd.energy_per_bit_j
    )
    dyn_e = traffic.total_bits * per_bit
    switch_e = traffic.n_transfers * net.n_stages * d.mzi.switch_energy_j
    energy = static_p * lat + dyn_e + switch_e

    return NetworkReport(
        name=net.name,
        power_w=float(static_p + (dyn_e + switch_e) / max(lat, 1e-30)),
        latency_s=float(lat),
        energy_j=float(energy),
        energy_per_bit_j=float(energy / max(traffic.total_bits, 1.0)),
        laser_power_w=laser_p,
        trimming_power_w=float(trimming_p),
    )

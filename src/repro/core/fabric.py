"""Fabric: one network design point as the Layer-B link model.

The bridge between the two halves of this repo.  Layer A (core.topology /
core.power / core.search) scores interposer-network *designs*; Layer B
(launch.hlo_analysis, core.planner, parallel.collectives) prices *programs*
— roofline terms, collective schedules, channel plans — but historically did
so against hard-coded metallic-link constants (50 GB/s ICI).  A `Fabric`
converts any design point — a named preset, a `NetworkModel`, a config dict
from `GridSpec.config_at` / `codesign_config_at`, or a whole
`core.search` Pareto frontier — into the link numbers the Layer-B estimate
path consumes:

  cross_pod_bw_bytes_per_s   the slow inter-pod link (replaces ICI_BW in the
                             roofline collective term and the channel
                             planner): effective_bw_bps / 8 of the network.
  intra_pod_bw_bytes_per_s   subnetwork-provisioned bandwidth inside a pod
                             (aggregate_bw_bps / 8 — parallel subnetworks /
                             waveguides all usable for local stages).
  link_latency_s             fixed per-collective overhead (arbitration or
                             MZI switching), from per_transfer_s.
  energy_per_bit_j           network energy per wire bit, from the Layer-A
                             power model under a probe traffic.
  hbm_bw_bytes_per_s /       chip-local constants, carried so a Fabric fully
  peak_flops                 determines a roofline evaluation.

`DEFAULT_FABRIC` is the metallic-ICI TPU-class preset and reproduces the
pre-fabric constants exactly (its link latency is 0: the old model lumped
per-hop costs into the bandwidth term), so estimates under the default are
byte-identical to the historical path.

Entry points:

  metallic_ici() / FABRIC_PRESETS / get_fabric(name)
  Fabric.from_network_model(net)       any core.topology NetworkModel
  Fabric.from_config(cfg)              a config dict (topology + axis
                                       overrides) as emitted by
                                       GridSpec.config_at or
                                       codesign_config_at
  fabrics_from_front(front, spec)      one Fabric per distinct network
                                       design on a Pareto frontier — the
                                       search -> system loop closed
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, TYPE_CHECKING

import numpy as np

from repro.core.devices import DeviceLibrary, DEFAULT_DEVICES
from repro.core.power import Traffic, evaluate_network
from repro.core.topology import (
    NetworkModel,
    NetworkParams,
    model_from_row,
    TOPOLOGY_ARRAYS,
    sprint_bus,
    spacx_bus,
    tree_network,
    trine_network,
    electrical_mesh,
)

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.search import ParetoFront
    from repro.core.sweep import GridSpec

__all__ = [
    "Fabric", "DEFAULT_FABRIC", "FABRIC_PRESETS", "get_fabric",
    "metallic_ici", "fabrics_from_front", "degrade", "overlapped_step_s",
    "DEFAULT_PEAK_FLOPS", "DEFAULT_HBM_BW", "METALLIC_ICI_BW",
]

# TPU v5e-class chip constants (per assignment); the single source of truth —
# launch.hlo_analysis re-exports these as PEAK_FLOPS / HBM_BW / ICI_BW.
DEFAULT_PEAK_FLOPS = 197e12    # bf16 FLOP/s per chip
DEFAULT_HBM_BW = 819e9         # bytes/s HBM per chip
METALLIC_ICI_BW = 50e9         # bytes/s per metallic ICI link

# probe traffic used to extract an energy-per-bit figure from the Layer-A
# power model (large enough that per-transfer overheads are amortized)
_PROBE = Traffic(bytes_read=1 << 30, bytes_written=1 << 30, n_transfers=16)

# config-dict keys that describe the accelerator's compute side, not the
# interposer link model — `from_config`/`fabrics_from_front` drop them
_COMPUTE_SIDE_KEYS = ("mix", "chiplets", "mac_rate_hz",
                      "lambda_slot_energy_j")


@dataclasses.dataclass(frozen=True)
class Fabric:
    """One network design point, reduced to the Layer-B link model."""

    name: str
    cross_pod_bw_bytes_per_s: float
    intra_pod_bw_bytes_per_s: float
    hbm_bw_bytes_per_s: float = DEFAULT_HBM_BW
    peak_flops: float = DEFAULT_PEAK_FLOPS
    link_latency_s: float = 0.0       # fixed per-collective overhead
    energy_per_bit_j: float = 0.0     # network energy per wire bit
    source: Dict[str, float] = dataclasses.field(default_factory=dict)

    # ---- roofline terms -------------------------------------------------
    def compute_s(self, flops: float) -> float:
        return flops / self.peak_flops

    def memory_s(self, hbm_bytes: float) -> float:
        return hbm_bytes / self.hbm_bw_bytes_per_s

    def collective_s(self, wire_bytes: float, n_collectives: float = 0.0
                     ) -> float:
        """Serialization on the slow (cross-pod) link + fixed per-collective
        switching/arbitration overhead."""
        return (wire_bytes / self.cross_pod_bw_bytes_per_s
                + n_collectives * self.link_latency_s)

    def collective_energy_j(self, wire_bytes: float) -> float:
        return 8.0 * wire_bytes * self.energy_per_bit_j

    # ---- constructors ---------------------------------------------------
    @classmethod
    def from_network_model(
        cls,
        net: NetworkModel,
        name: Optional[str] = None,
        devices: Optional[DeviceLibrary] = None,
        *,
        hbm_bw_bytes_per_s: float = DEFAULT_HBM_BW,
        peak_flops: float = DEFAULT_PEAK_FLOPS,
        source: Optional[Mapping[str, float]] = None,
    ) -> "Fabric":
        """Reduce a Layer-A `NetworkModel` to fabric link numbers.

        Cross-pod bandwidth is the *effective* (contention-derated) network
        bandwidth — the shared stage every hierarchical collective must
        cross; intra-pod bandwidth is the aggregate (subnetworks/waveguides
        run in parallel for pod-local stages).  Energy per bit comes from
        the full Layer-A power model under a probe traffic, so laser sizing
        and trimming are amortized in, not just the dynamic term.
        """
        rep = evaluate_network(net, _PROBE, devices or DEFAULT_DEVICES)
        cross = net.effective_bw_bps / 8.0
        intra = max(net.aggregate_bw_bps / 8.0, cross)
        return cls(
            name=name or net.name,
            cross_pod_bw_bytes_per_s=cross,
            intra_pod_bw_bytes_per_s=intra,
            hbm_bw_bytes_per_s=hbm_bw_bytes_per_s,
            peak_flops=peak_flops,
            link_latency_s=net.per_transfer_s,
            energy_per_bit_j=rep.energy_per_bit_j,
            source=dict(source or {}),
        )

    @classmethod
    def from_config(
        cls,
        cfg: Mapping[str, object],
        name: Optional[str] = None,
        devices: Optional[DeviceLibrary] = None,
        **kwargs,
    ) -> "Fabric":
        """Build a Fabric from a config dict — the format `GridSpec.
        config_at`, `SweepResult.config_at`, `codesign_config_at`, and
        `refine_codesign`'s refined point emit: a "topology" key plus
        swept-axis overrides (NetworkParams fields, dotted device leaves,
        "n_subnetworks").  Compute-side keys ("mix", "chiplets",
        "mac_rate_hz", "lambda_slot_energy_j") are ignored: they change the
        accelerator's compute, not the interposer link model."""
        from repro.core.sweep import grid_spec  # local: avoid import cycle

        cfg = dict(cfg)
        topology = str(cfg.pop("topology"))
        for key in _COMPUTE_SIDE_KEYS:
            cfg.pop(key, None)
        if topology not in TOPOLOGY_ARRAYS:
            raise KeyError(f"unknown topology {topology!r}")
        spec = grid_spec((topology,), devices=devices)
        cols = dict(spec.base)
        for k, v in cfg.items():
            if k not in cols:
                raise KeyError(f"unknown config column {k!r}")
            cols[k] = float(v)
        cols_arr = {k: np.float64(v) for k, v in cols.items()}
        net = model_from_row(TOPOLOGY_ARRAYS[topology](cols_arr),
                             topology)
        src = {"topology": topology}
        src.update({k: float(v) for k, v in cfg.items()})
        return cls.from_network_model(
            net, name=name or f"{topology}-cfg", devices=devices,
            source=src, **kwargs)


def metallic_ici() -> Fabric:
    """TPU-class metallic baseline: the pre-fabric hard-coded link model.
    Link latency is 0 because the historical model lumped per-hop costs into
    the bandwidth term — keeping it makes default-fabric estimates
    byte-identical to the old constants.  ~5 pJ/bit is a typical electrical
    SerDes + wire figure."""
    return Fabric(
        name="metallic_ici",
        cross_pod_bw_bytes_per_s=METALLIC_ICI_BW,
        intra_pod_bw_bytes_per_s=METALLIC_ICI_BW,
        hbm_bw_bytes_per_s=DEFAULT_HBM_BW,
        peak_flops=DEFAULT_PEAK_FLOPS,
        link_latency_s=0.0,
        energy_per_bit_j=5e-12,
    )


DEFAULT_FABRIC = metallic_ici()


def _preset(factory, name: str, topology: str) -> Fabric:
    # the topology key in `source` lets `degrade` rebuild the design point
    # exactly (the same columnar path `from_config` takes)
    return Fabric.from_network_model(factory(NetworkParams()), name=name,
                                     source={"topology": topology})


FABRIC_PRESETS = {
    "metallic_ici": metallic_ici,
    "trine_siph": lambda: _preset(trine_network, "trine_siph", "trine"),
    "tree_siph": lambda: _preset(tree_network, "tree_siph", "tree"),
    "sprint_siph": lambda: _preset(sprint_bus, "sprint_siph", "sprint"),
    "spacx_siph": lambda: _preset(spacx_bus, "spacx_siph", "spacx"),
    "elec_mesh": lambda: _preset(electrical_mesh, "elec_mesh", "elec"),
}


def get_fabric(fabric) -> Fabric:
    """Resolve a Fabric, a preset name, or pass through None -> default."""
    if fabric is None:
        return DEFAULT_FABRIC
    if isinstance(fabric, Fabric):
        return fabric
    if isinstance(fabric, str):
        if fabric not in FABRIC_PRESETS:
            raise KeyError(
                f"unknown fabric preset {fabric!r}; presets: "
                f"{sorted(FABRIC_PRESETS)}")
        return FABRIC_PRESETS[fabric]()
    raise TypeError(f"expected Fabric | preset name | None, got {fabric!r}")


def fabrics_from_front(
    front: "ParetoFront",
    spec: "GridSpec",
    mixes: Optional[Sequence] = None,
    devices: Optional[DeviceLibrary] = None,
    max_fabrics: Optional[int] = None,
    prefix: str = "pareto",
    **kwargs,
) -> List[Fabric]:
    """One Fabric per *distinct network design* on a Pareto frontier.

    Frontier rows from `codesign_pareto` encode (chiplet mix x network
    config); different mixes over the same network collapse to one fabric
    (the mix changes compute, not the link model).  Fabrics are named
    ``{prefix}:{topology}@{flat_index}`` so what-if artifacts trace back to
    the exact frontier row.  `max_fabrics` keeps what-if tables bounded
    (first-come in the front's canonical order)."""
    from repro.core.search import frontier_configs  # local: import cycle

    out: List[Fabric] = []
    seen = set()
    for idx, cfg in zip(front.indices, frontier_configs(front, spec, mixes)):
        net_cfg = {k: v for k, v in cfg.items()
                   if k not in _COMPUTE_SIDE_KEYS}
        key = tuple(sorted((k, float(v) if k != "topology" else v)
                           for k, v in net_cfg.items()))
        if key in seen:
            continue
        seen.add(key)
        out.append(Fabric.from_config(
            net_cfg, name=f"{prefix}:{net_cfg['topology']}@{int(idx)}",
            devices=devices, **kwargs))
        if max_fabrics is not None and len(out) >= max_fabrics:
            break
    return out


# --------------------------------------------------------------------------
# Fault degradation (core.faults threaded into the Layer-B link model)
# --------------------------------------------------------------------------


def degrade(fabric, scenario) -> Fabric:
    """The Layer-B view of a fault scenario: re-derive a fabric's link
    numbers under `scenario` (a scalar `core.faults.FaultScenario`).

    Fabrics whose `source` names a topology (presets, `from_config`,
    frontier fabrics) take the exact columnar path: rebuild the design
    point's columns, degrade them through `core.faults`, and reduce the
    degraded fields to cross/intra-pod bandwidth, per-hop latency, and
    energy/bit — so laser aging and thermal drift show up as a higher
    energy_per_bit_j, and dead banks/wavelengths as lower bandwidth.
    Sourceless fabrics (the metallic baseline) only expose gateway ports to
    failure: bandwidth scales by the surviving-port fraction.

    Degradation composes from the *healthy* source design — pass cumulative
    scenarios rather than chaining degrade() calls.
    """
    from repro.core import faults as F  # runtime import: faults layers above
    from repro.core.sweep import evaluate_columns, grid_spec

    fb = get_fabric(fabric)
    if scenario.batch_shape():
        raise ValueError("degrade takes one scalar scenario; fold batches "
                         "through core.faults.availability_search instead")
    name = f"{fb.name}|{scenario.name}"
    topology = fb.source.get("topology")
    if topology is None:
        surv = float(F.port_survival(scenario))
        return dataclasses.replace(
            fb, name=name,
            cross_pod_bw_bytes_per_s=fb.cross_pod_bw_bytes_per_s * surv,
            intra_pod_bw_bytes_per_s=fb.intra_pod_bw_bytes_per_s * surv,
            source=dict(fb.source, degraded=1.0))

    spec = grid_spec((str(topology),))
    cols = dict(spec.base)
    for k, v in fb.source.items():
        if k in cols:
            cols[k] = float(v)
    cols = {k: np.atleast_1d(np.float64(v)) for k, v in cols.items()}
    nets, dcols = F.degraded_network_columns(
        cols, np.zeros(1, np.int64), (str(topology),), scenario)
    eff = float(np.ravel(nets["effective_bw_bps"])[0])
    agg = float(np.ravel(nets["aggregate_bw_bps"])[0])
    cross = eff / 8.0
    if eff > 0:
        rep = evaluate_columns(nets, dcols, _PROBE.total_bits,
                               _PROBE.n_transfers)
        epb = float(np.ravel(rep["energy_per_bit_j"])[0])
    else:
        epb = float("inf")  # no surviving lanes: nothing can cross
    return dataclasses.replace(
        fb, name=name,
        cross_pod_bw_bytes_per_s=cross,
        intra_pod_bw_bytes_per_s=max(agg / 8.0, cross),
        link_latency_s=float(np.ravel(nets["per_transfer_s"])[0]),
        energy_per_bit_j=epb,
        source=dict(fb.source, degraded=1.0))


def overlapped_step_s(compute_s: float, wire_bytes: float, fabric,
                      channels: int) -> float:
    """Modeled train-step time when a `wire_bytes` collective overlaps a
    `compute_s` window through `channels` parallel chunks.  The first chunk
    has nothing to hide behind, so only (1 - 1/channels) of the compute
    window is usable cover — more channels on a degraded (slower) fabric
    recover throughput, which is what replanning buys."""
    fb = get_fabric(fabric)
    if fb.cross_pod_bw_bytes_per_s <= 0:
        return float("inf")
    channels = max(1, int(channels))
    comm = fb.collective_s(wire_bytes, n_collectives=channels)
    cover = compute_s * (1.0 - 1.0 / channels)
    return compute_s + max(0.0, comm - cover)

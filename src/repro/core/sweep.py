"""Vectorized design-space sweep engine for the interposer-network models.

The paper's headline figures come from sweeping network configurations across
gateways / wavelengths / modulation rates / device corners.  The scalar
dataclass path (`NetworkParams` -> `NetworkModel` -> `evaluate_network`)
evaluates one configuration per Python call; this module flattens whole
parameter grids into struct-of-arrays columns and evaluates every metric the
power model produces — laser, trimming, latency, energy, energy-per-bit — for
10k+ configurations in one jitted call.

Pipeline:

  build_grid(...)          cartesian product of a topology axis, any
                           NetworkParams field, any dotted DeviceLibrary leaf
                           ("mzi.insertion_loss_db", ...), and the TRINE
                           "n_subnetworks" override -> SweepGrid of float64
                           columns.
  network_columns(grid)    struct-of-arrays NetworkModel fields, via the
                           columnar topology kernels in core.topology.
  evaluate_columns(...)    the jitted batched power/latency/energy kernel
                           (mirrors power.evaluate_network branch-free).
  sweep(traffic, ...)      all of the above in one call -> SweepResult.

`sweep_scalar_reference` walks the identical grid through the scalar
dataclass path one row at a time; it is the golden reference the parity tests
(and benchmarks/sweep_bench.py) compare the batched engine against.

`evaluate_accelerator_batch` is the same treatment for the Fig. 6 accelerator
model: all layers of a workload evaluated as one batch instead of a Python
loop per layer.

Device-resident streaming execution
-----------------------------------

`sweep(...)` materializes every grid column in host memory — ~45 float64
columns, so a 1e7-point grid costs ~3.6 GB before a single metric exists.
The streaming path bounds that AND keeps the hot loop off the host:

  grid_spec(...)           the same validation/axis vocabulary as
                           `build_grid`, but *lazy*: a GridSpec holds only
                           the axis value tuples and can materialize any
                           [start, stop) row window in O(window) memory
                           (mixed-radix decode of the flat index).
  sweep_chunked(traffic, reducer, ...)
                           streams fixed-size chunks through one universal
                           jitted chunk program, feeding each chunk's metrics
                           to a running `ChunkReducer` and keeping nothing
                           else.  Peak memory is O(chunk_size), independent
                           of grid size.

Two materialization modes feed the same chunk program:

  materialize="device"     (default) a chunk is generated from the `start`
                           scalar alone: a jitted mixed-radix *decode
                           program* gathers each column from small
                           device-resident axis-value tables, so steady-state
                           streaming performs zero per-chunk host numpy work
                           and zero per-chunk H2D column transfers.
  materialize="host"       the serial reference layout: `GridSpec.chunk_cols`
                           builds the columns on the host (the golden
                           mixed-radix decode the device program is
                           parity-tested against) and ships them to the
                           device.  Forced when ``shard=True`` (columns are
                           laid out across devices with NamedSharding) or
                           when a legacy `columns_fn` callable needs host
                           columns.

Both modes hand the *same* program instance the same column values, so their
reducer folds are bit-identical; `chunk_cols` stays the golden host
reference.  All engine programs trace AND execute under float64
(`power.engine_x64`), independent of the session-wide x64 setting —
bit-reproducibility across chunk boundaries requires one fixed precision.

On top of either mode sits a double-buffered prefetch pipeline: a
single-worker executor enqueues chunk k+1 while chunk k's results fold on
the main thread (`jax.block_until_ready` at the fold point — XLA releases
the GIL during device execution, so reducer host work overlaps device
compute).  The depth comes from ``prefetch=`` or the REPRO_PREFETCH
environment flag (default 2); depth 0 is the fully serial schedule.  Folds
happen in chunk order regardless of depth, so any depth produces
bit-identical reducer states.

The fault hook composes on-device: `faults.faulted_columns_fn(scenario)`
returns a scenario-carrying hook whose six fields become *runtime inputs* of
the chunk program (degradation algebra traced, not re-compiled per
scenario).  A healthy scenario feeds exact IEEE identities (x+0, x*1), so a
faulted-healthy sweep is bitwise equal to a plain sweep.  Arbitrary legacy
``columns_fn(cols, topo_id, topologies) -> (nets, dev_cols)`` callables
still run on host-materialized columns.

On non-CPU backends the chunk program donates its column buffers
(`donate_argnums`), so steady-state chunk evaluation reuses device memory.

Reducers are associative folds over chunks: `MinReducer` tracks a metric's
running argmin + config, `core.search.ParetoReducer` keeps the running
(latency, energy, power) Pareto front via the merge-fronts property
front(A ∪ B) = front(front(A) ∪ front(B)).
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.env import prefetch_depth
from repro.core.devices import (
    DeviceLibrary,
    DEFAULT_DEVICES,
    device_columns,
    replace_device_leaves,
)
from repro.core.topology import (
    MODEL_FIELDS,
    PARAM_FIELDS,
    TOPOLOGIES,
    TOPOLOGY_ARRAYS,
    NetworkParams,
    model_from_row,
)
from repro.core.power import (
    EVAL_DEVICE_FIELDS,
    EVAL_METRIC_FIELDS,
    Traffic,
    broadcast_metrics,
    engine_x64,
    eval_network_math as eval_math,
    evaluate_network,
)
from repro.core.accelerator import (  # noqa: F401  (re-exported; see below)
    evaluate_accelerator_batch,
    evaluate_accelerator_grid,
)

__all__ = [
    "SweepGrid", "SweepResult", "build_grid", "network_columns",
    "network_columns_device",
    "evaluate_columns", "sweep", "sweep_scalar_reference",
    "evaluate_accelerator_batch", "METRIC_FIELDS", "INTEGER_AXES",
    "DEFAULT_TOPOLOGIES",
    "GridSpec", "grid_spec", "SweepChunk", "ChunkReducer", "MinReducer",
    "sweep_chunked", "eval_math",
]

DEFAULT_TOPOLOGIES: Tuple[str, ...] = ("sprint", "spacx", "tree", "trine", "elec")

# int-typed NetworkParams fields (scalar-reference reconstruction)
_INT_PARAM_FIELDS = frozenset({"n_gateways", "n_mem_chiplets", "n_lambda",
                               "gateway_width_bits"})

# grid axes whose admissible values are integers: the int NetworkParams
# fields plus the TRINE subnetwork override.  `core.search.refine_codesign`
# snaps relaxed values of these axes back to integer neighbors during
# round-and-rescore; everything else in the axis vocabulary is continuous.
INTEGER_AXES = _INT_PARAM_FIELDS | {"n_subnetworks"}

# metric columns emitted by the batched evaluator == NetworkReport fields
# (defined in core.power next to the math that emits them)
METRIC_FIELDS = EVAL_METRIC_FIELDS

# device leaves the power kernel reads (re-exported; defined in core.power
# next to the shared metric math)
_EVAL_DEVICE_FIELDS = EVAL_DEVICE_FIELDS


# --------------------------------------------------------------------------
# Grid construction
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Lazy cartesian grid: the axis vocabulary and defaults of `build_grid`
    without the materialized columns.  Any [start, stop) row window can be
    produced on demand by mixed-radix decoding the flat index, so a window
    costs O(window) memory regardless of grid size — the foundation of
    `sweep_chunked`'s bounded-memory streaming evaluation.

    axis order: ("topology", *axes), C-order raveled — identical flat-index
    layout to the eager SweepGrid `build_grid` returns.
    """

    topologies: Tuple[str, ...]
    axes: Dict[str, Tuple[float, ...]]
    base: Dict[str, float]
    shape: Tuple[int, ...]

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    def chunk_cols(self, start: int, stop: int
                   ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """(cols, topo_id) for flat rows [start, stop) — element-for-element
        the values eager `build_grid` places at those rows.  The golden host
        reference the jitted decode program is parity-tested against."""
        idx = np.arange(start, stop)
        digits = np.unravel_index(idx, self.shape)
        cols = {name: np.full(idx.size, v, np.float64)
                for name, v in self.base.items()}
        for ai, (name, vals) in enumerate(self.axes.items()):
            cols[name] = np.asarray(vals, np.float64)[digits[1 + ai]]
        return cols, np.ascontiguousarray(digits[0])

    def config_at(self, i: int) -> Dict[str, float]:
        """Human-readable swept-axis settings of flat row `i`."""
        digits = np.unravel_index(int(i), self.shape)
        out: Dict[str, float] = {"topology": self.topologies[int(digits[0])]}
        for ai, (name, vals) in enumerate(self.axes.items()):
            out[name] = float(vals[int(digits[1 + ai])])
        return out


def grid_spec(
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    **axes: Sequence[float],
) -> GridSpec:
    """Validate and describe a grid without materializing it (see
    `build_grid` for the axis vocabulary)."""
    base: Dict[str, float] = {name: float(getattr(NetworkParams(), name))
                              for name in PARAM_FIELDS}
    base.update(device_columns(devices or DEFAULT_DEVICES))
    base["n_subnetworks"] = 0.0

    for name in axes:
        if name not in base:
            raise KeyError(
                f"unknown sweep axis {name!r}; valid axes are NetworkParams "
                f"fields, dotted device leaves, or 'n_subnetworks'")
    unknown = [t for t in topologies if t not in TOPOLOGY_ARRAYS]
    if unknown:
        raise KeyError(f"unknown topologies {unknown!r}")

    axes_vals = {k: tuple(float(x) for x in v) for k, v in axes.items()}
    shape = (len(topologies),) + tuple(len(v) for v in axes_vals.values())
    return GridSpec(topologies=tuple(topologies), axes=axes_vals,
                    base=base, shape=shape)


def _validate_grid_values(spec: GridSpec) -> None:
    """Eager data-dependent validation the traced chunk program cannot do.

    The numpy SPACX kernel raises on n_gateways < 8 (zero clusters => zero
    bandwidth); the traced kernel evaluates every topology on every lane and
    selects, so it cannot raise data-dependently.  The grid is cartesian —
    every gateway value reaches the SPACX lanes — so the whole-axis check is
    exactly the condition the per-chunk numpy kernel would have tripped on.
    """
    if "spacx" not in spec.topologies:
        return
    gvals = spec.axes.get("n_gateways") or (spec.base["n_gateways"],)
    if min(gvals) < 8:
        raise ValueError(
            "SPACX requires n_gateways >= 8 (one 8-gateway cluster minimum; "
            "fewer means zero clusters and zero bandwidth)")


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A flattened cartesian parameter grid (struct-of-arrays columns).

    axis order: ("topology", *axes) — `shape` follows it, every column and
    `topo_id` is raveled to length `n = prod(shape)`.
    """

    topologies: Tuple[str, ...]
    axes: Dict[str, Tuple[float, ...]]
    cols: Dict[str, np.ndarray]
    topo_id: np.ndarray
    shape: Tuple[int, ...]

    @property
    def n(self) -> int:
        return int(self.topo_id.size)

    @functools.cached_property
    def topo_masks(self) -> Tuple[np.ndarray, ...]:
        """Per-topology boolean row masks, computed once per grid object and
        reused by every `network_columns` call on it (cached_property writes
        to the instance __dict__, bypassing the frozen-dataclass setattr)."""
        return tuple(self.topo_id == ti for ti in range(len(self.topologies)))

    def row_params(self, i: int) -> NetworkParams:
        kw = {}
        for name in PARAM_FIELDS:
            v = self.cols[name][i]
            kw[name] = int(v) if name in _INT_PARAM_FIELDS else float(v)
        return NetworkParams(**kw)

    def row_devices(self, i: int,
                    base: Optional[DeviceLibrary] = None) -> DeviceLibrary:
        base = base or DEFAULT_DEVICES
        swept = {k: float(self.cols[k][i]) for k in self.axes if "." in k}
        return replace_device_leaves(base, swept) if swept else base

    def row_topology(self, i: int) -> str:
        return self.topologies[int(self.topo_id[i])]


def build_grid(
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    **axes: Sequence[float],
) -> SweepGrid:
    """Cartesian product of `topologies` x every keyword axis.

    Axis names may be NetworkParams fields (``n_gateways=(16, 32, 64)``),
    dotted DeviceLibrary leaves (``mzi.insertion_loss_db`` — pass via a dict
    expansion since dots aren't identifiers: ``**{"mzi.insertion_loss_db":
    (1.0, 2.0)}``), or ``n_subnetworks`` (TRINE K override; 0 = bandwidth-
    matched auto).  Unswept columns take their NetworkParams/DeviceLibrary
    defaults.
    """
    spec = grid_spec(topologies, devices=devices, **axes)
    cols, topo_id = spec.chunk_cols(0, spec.n)
    return SweepGrid(topologies=spec.topologies, axes=spec.axes,
                     cols=cols, topo_id=topo_id, shape=spec.shape)


def _network_columns_arrays(cols: Mapping[str, np.ndarray],
                            topo_id: np.ndarray,
                            topologies: Sequence[str],
                            masks: Optional[Sequence[np.ndarray]] = None,
                            ) -> Dict[str, np.ndarray]:
    """Struct-of-arrays NetworkModel fields for (cols, topo_id) rows (host
    numpy reference path).  `masks` short-circuits the per-topology row-mask
    computation with precomputed masks (see `SweepGrid.topo_masks`)."""
    out = {f: np.zeros(topo_id.size, np.float64) for f in MODEL_FIELDS}
    for ti, name in enumerate(topologies):
        mask = masks[ti] if masks is not None else topo_id == ti
        if not mask.any():
            continue  # chunk windows may not contain every topology
        sub = {k: v[mask] for k, v in cols.items()}
        fields = TOPOLOGY_ARRAYS[name](sub)
        for f in MODEL_FIELDS:
            out[f][mask] = fields[f]
    return out


def network_columns(grid: SweepGrid) -> Dict[str, np.ndarray]:
    """Struct-of-arrays NetworkModel fields for every grid row."""
    return _network_columns_arrays(grid.cols, grid.topo_id, grid.topologies,
                                   masks=grid.topo_masks)


# --------------------------------------------------------------------------
# Batched evaluation (the jitted kernels)
# --------------------------------------------------------------------------

# the metric math itself lives in core.power.eval_network_math (shared with
# the co-design accelerator kernel and the gradient-refinement path); this
# module owns the jit/donation/sharding machinery around it
_eval_kernel = jax.jit(eval_math)
# donating nets/dev lets XLA reuse the chunk input buffers for the outputs in
# steady-state streaming; CPU ignores donation (and warns), so gate on backend
_eval_kernel_donated = jax.jit(eval_math, donate_argnums=(0, 1))


def _chunk_eval_kernel():
    return (_eval_kernel if jax.default_backend() == "cpu"
            else _eval_kernel_donated)


def _as_f64(x):
    # float64 whenever x64 is enabled (the engine always enters engine_x64()
    # around conversions + kernel calls), float32 otherwise — jnp downcasts
    return jnp.asarray(np.asarray(x, np.float64))


def evaluate_columns(
    nets: Mapping[str, np.ndarray],
    cols: Mapping[str, np.ndarray],
    total_bits,
    n_transfers,
    active_fraction=1.0,
) -> Dict[str, np.ndarray]:
    """Run the jitted batched evaluator over struct-of-arrays NetworkModel
    fields.  `total_bits` / `n_transfers` / `active_fraction` broadcast
    against the config axis (e.g. shape (W, 1) traffic x (N,) configs ->
    (W, N) metrics).  Always evaluates in float64 (`engine_x64`), matching
    the streaming engine's fixed precision."""
    with engine_x64():
        nets_j = {k: _as_f64(nets[k]) for k in MODEL_FIELDS}
        dev_j = {k: _as_f64(cols[k]) for k in _EVAL_DEVICE_FIELDS}
        out = _eval_kernel(nets_j, dev_j, _as_f64(total_bits),
                           _as_f64(n_transfers), _as_f64(active_fraction))
        out = {k: np.asarray(v, np.float64) for k, v in out.items()}
    # static-only metrics (laser, trimming) don't see the traffic operands;
    # broadcast everything to the full (traffic x config) result shape
    return broadcast_metrics(out, np)


# ---- the universal chunk programs -----------------------------------------
#
# Bitwise reproducibility across execution modes pins the program structure:
# two *different* jit programs of the same math may fuse FMAs differently and
# disagree in the last ulp, but one program instance is bitwise-stable across
# input shapes.  So there is exactly ONE evaluation program per topology
# tuple — shared by `sweep` (full shape), host-materialized chunks, and
# device-decoded chunks — and the mixed-radix decode is a SEPARATE program
# whose gather outputs are exact (bit-identical to `GridSpec.chunk_cols`),
# rather than being fused into the evaluation (fusion would change the
# evaluation's FMA decisions and break monolithic-vs-chunked parity).

_DECODE_PROGRAMS: Dict[tuple, Callable] = {}
_ENGINE_PROGRAMS: Dict[tuple, Callable] = {}
_NETS_PROGRAMS: Dict[tuple, Callable] = {}


def _decode_program(spec: GridSpec, chunk: int) -> Callable:
    """Jitted mixed-radix decode: (axis tables, base scalars, start) ->
    (cols, topo_id) for flat rows [start, start+chunk), clamped to the last
    row — exactly `chunk_cols`' repeat-last-row padding.  Gathers and integer
    strides are exact, so the decoded columns are bit-identical to the host
    reference."""
    key = (spec.shape, tuple(spec.axes), tuple(spec.base), int(chunk))
    fn = _DECODE_PROGRAMS.get(key)
    if fn is not None:
        return fn
    shape = spec.shape
    n = int(np.prod(shape))
    strides = tuple(int(np.prod(shape[i + 1:], dtype=np.int64))
                    for i in range(len(shape)))
    axes_names = tuple(spec.axes)
    base_names = tuple(spec.base)

    def decode(tables, base, start):
        idx = jnp.minimum(start + jnp.arange(chunk), n - 1)
        cols = {name: jnp.broadcast_to(base[name], (chunk,))
                for name in base_names}
        for ai, name in enumerate(axes_names):
            digit = (idx // strides[1 + ai]) % shape[1 + ai]
            cols[name] = tables[name][digit]
        return cols, idx // strides[0]

    fn = jax.jit(decode)
    _DECODE_PROGRAMS[key] = fn
    return fn


def _engine_program(topologies: Tuple[str, ...], donate: bool) -> Callable:
    """The universal chunk-evaluation program: (cols, topo_id, scenario,
    bits, xfers, frac) -> (nets, metrics).

    Every topology kernel evaluates on every lane and `topo_id` selects —
    the traced mirror of `_network_columns_arrays`' masking.  The fault
    algebra (`core.faults`) is part of the trace with the six scenario
    fields as runtime inputs: a healthy scenario feeds exact IEEE identities
    (x + 0.0, x * 1.0, banks/banks), so plain and faulted-healthy sweeps are
    bitwise equal without a second program.  Metrics come back broadcast to
    the common (traffic x scenario x config) shape so padded lanes slice off
    uniformly."""
    key = (tuple(topologies), bool(donate))
    fn = _ENGINE_PROGRAMS.get(key)
    if fn is not None:
        return fn
    # runtime import: core.faults imports this module at load time
    from repro.core import faults as _faults

    def body(cols, topo_id, scen, bits, xfers, frac):
        scenario = _faults.FaultScenario(**scen)
        dcols = _faults.degrade_device_columns(cols, scenario, jnp)
        nets = None
        for ti, name in enumerate(topologies):
            fields = TOPOLOGY_ARRAYS[name](dcols, jnp)
            fields = _faults._degrade_fields(
                fields, cols["n_gateways"], scenario, name, jnp)
            sel = topo_id == ti
            if nets is None:
                nets = {f: jnp.where(sel, fields[f],
                                     jnp.zeros_like(fields[f]))
                        for f in MODEL_FIELDS}
            else:
                nets = {f: jnp.where(sel, fields[f], nets[f])
                        for f in MODEL_FIELDS}
        dev = {k: dcols[k] for k in _EVAL_DEVICE_FIELDS}
        metrics = broadcast_metrics(
            eval_math(nets, dev, bits, xfers, frac), jnp)
        return nets, metrics

    fn = jax.jit(body, donate_argnums=(0,)) if donate else jax.jit(body)
    _ENGINE_PROGRAMS[key] = fn
    return fn


def _engine_kernel(topologies: Sequence[str]) -> Callable:
    """Backend-appropriate universal chunk program (donation off on CPU)."""
    return _engine_program(tuple(topologies),
                           donate=jax.default_backend() != "cpu")


def _nets_program(topologies: Tuple[str, ...]) -> Callable:
    """Jitted healthy network-column builder: (cols, topo_id) -> (nets,
    mem_bw_bytes_per_s_total).  The co-design search routes BOTH its
    materialization modes through this one instance so their fronts are
    bit-identical; `network_columns_device` exposes the nets to host callers
    (benchmark/bruteforce parity)."""
    key = tuple(topologies)
    fn = _NETS_PROGRAMS.get(key)
    if fn is not None:
        return fn

    def body(cols, topo_id):
        nets = None
        for ti, name in enumerate(topologies):
            fields = TOPOLOGY_ARRAYS[name](cols, jnp)
            sel = topo_id == ti
            if nets is None:
                nets = {f: jnp.where(sel, fields[f],
                                     jnp.zeros_like(fields[f]))
                        for f in MODEL_FIELDS}
            else:
                nets = {f: jnp.where(sel, fields[f], nets[f])
                        for f in MODEL_FIELDS}
        mem_bw = cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"]
        return nets, mem_bw

    fn = jax.jit(body)
    _NETS_PROGRAMS[key] = fn
    return fn


def network_columns_device(cols: Mapping[str, np.ndarray],
                           topo_id: np.ndarray,
                           topologies: Sequence[str],
                           ) -> Dict[str, np.ndarray]:
    """Traced-kernel network columns as host float64 — the device-path
    analog of `_network_columns_arrays`, bit-identical to the nets the
    streaming co-design engine evaluates (XLA and numpy transcendentals
    differ in the last ulp, so exact-front comparisons against the engine
    must build their reference nets here, not on the numpy path)."""
    prog = _nets_program(tuple(topologies))
    with engine_x64():
        cols_j = {k: _as_f64(v) for k, v in cols.items()}
        nets, _ = prog(cols_j, jnp.asarray(np.asarray(topo_id)))
        return {k: np.asarray(v, np.float64) for k, v in nets.items()}


def _scenario_inputs(scenario=None) -> Dict[str, jax.Array]:
    """The six fault-scenario operands as device arrays (healthy identity
    values when None).  Must be called under `engine_x64`."""
    from repro.core.faults import _SCENARIO_FIELDS, HEALTHY  # runtime: cycle
    s = HEALTHY if scenario is None else scenario
    return {f: _as_f64(getattr(s, f)) for f in _SCENARIO_FIELDS}


# --------------------------------------------------------------------------
# Top-level sweep API
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Metrics + model fields for every grid point (flat, length grid.n)."""

    grid: SweepGrid
    nets: Dict[str, np.ndarray]
    metrics: Dict[str, np.ndarray]

    def metric(self, name: str) -> np.ndarray:
        """One metric reshaped to the grid's (topology, *axes) shape."""
        return self.metrics[name].reshape(self.grid.shape)

    def config_at(self, i: int) -> Dict[str, float]:
        """Human-readable swept-axis settings of flat row `i`."""
        out: Dict[str, float] = {"topology": self.grid.row_topology(i)}
        for name in self.grid.axes:
            out[name] = float(self.grid.cols[name][i])
        return out

    def best(self, name: str = "energy_j") -> Tuple[int, Dict[str, float]]:
        """(flat index, swept-axis settings) of the metric's minimizer."""
        i = int(np.argmin(self.metrics[name]))
        return i, self.config_at(i)

    def model_at(self, i: int):
        """Scalar NetworkModel dataclass view of flat row `i`."""
        key = self.grid.row_topology(i)
        name = {"sprint": "SPRINT", "spacx": "SPACX", "tree": "Tree",
                "elec": "ElecMesh"}.get(key)
        if name is None:  # trine carries its subnetwork count
            name = f"TRINE-{int(self.nets['n_laser_banks'][i])}"
        return model_from_row(self.nets, name, i=i)


def sweep(
    traffic: Traffic,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
    **axes: Sequence[float],
) -> SweepResult:
    """Evaluate one workload's traffic over a full configuration grid.

    `nets` stays on the host numpy reference path (exact dataclass
    round-trips via `model_at`); the metrics run through the same universal
    chunk program the streaming paths use, at the full grid shape — one
    program instance is bitwise-stable across input shapes, which is what
    makes chunked results bit-identical to this monolithic call."""
    grid = build_grid(topologies, devices=devices, **axes)
    nets = network_columns(grid)  # host reference (also validates, eagerly)
    kernel = _engine_kernel(grid.topologies)
    with engine_x64():
        cols_j = {k: _as_f64(v) for k, v in grid.cols.items()}
        topo_j = jnp.asarray(np.asarray(grid.topo_id))
        out = kernel(cols_j, topo_j, _scenario_inputs(),
                     _as_f64(traffic.total_bits),
                     _as_f64(traffic.n_transfers), _as_f64(active_fraction))
        metrics = {k: np.asarray(v, np.float64) for k, v in out[1].items()}
    return SweepResult(grid=grid, nets=nets, metrics=metrics)


# --------------------------------------------------------------------------
# Chunked streaming evaluation (bounded memory for 1e7-point grids)
# --------------------------------------------------------------------------


def _traffic_arrays(traffic) -> Tuple[np.ndarray, np.ndarray]:
    """(total_bits, n_transfers) operands: scalar for one Traffic, (W, 1)
    columns for a sequence of workload traffics (broadcast against configs)."""
    if isinstance(traffic, Traffic):
        return np.float64(traffic.total_bits), np.float64(traffic.n_transfers)
    ts = list(traffic)
    bits = np.asarray([[t.total_bits] for t in ts], np.float64)
    xfers = np.asarray([[t.n_transfers] for t in ts], np.float64)
    return bits, xfers


@dataclasses.dataclass(frozen=True)
class SweepChunk:
    """One evaluated grid window [start, stop): metrics (and model fields)
    for those rows only.  `metrics` values have shape (..., stop-start) —
    a leading workload axis appears when the sweep batches traffics."""

    spec: GridSpec
    start: int
    stop: int
    topo_id: np.ndarray
    nets: Dict[str, np.ndarray]
    metrics: Dict[str, np.ndarray]

    @property
    def indices(self) -> np.ndarray:
        """Flat grid row indices of this chunk."""
        return np.arange(self.start, self.stop)


class ChunkReducer:
    """Associative fold over SweepChunks.  Implementations hold only running
    reductions (argmin scalars, Pareto fronts, histograms ...) so streaming
    sweeps stay O(chunk_size) regardless of grid size."""

    def init(self, spec: GridSpec):
        return None

    def step(self, carry, chunk: SweepChunk):
        raise NotImplementedError

    def finish(self, carry, spec: GridSpec):
        return carry


class MinReducer(ChunkReducer):
    """Running argmin of one metric — the bounded-memory `SweepResult.best`.
    Tracks per-workload minima when the sweep batches traffics."""

    def __init__(self, metric: str = "energy_j"):
        self.metric = metric

    def step(self, carry, chunk: SweepChunk):
        m = chunk.metrics[self.metric]
        j = np.argmin(m, axis=-1)
        v = np.take_along_axis(m, j[..., None], -1)[..., 0]
        i = chunk.start + j
        if carry is None:
            return v, i
        best_v, best_i = carry
        upd = v < best_v
        return np.where(upd, v, best_v), np.where(upd, i, best_i)

    def finish(self, carry, spec: GridSpec):
        if carry is None:
            raise ValueError("empty sweep")
        v, i = carry
        if np.ndim(i) == 0:
            return {"value": float(v), "index": int(i),
                    "config": spec.config_at(int(i))}
        flat_i = np.asarray(i).ravel()
        return {"value": np.asarray(v), "index": np.asarray(i),
                "config": [spec.config_at(int(k)) for k in flat_i]}


def _config_sharding():
    """NamedSharding over the config axis when >1 device is visible (the
    jax.sharding scale-out hook for grids past one device's memory); None on
    a single device."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    mesh = jax.sharding.Mesh(np.array(devs), ("configs",))
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("configs"))


def _run_pipeline(starts, make_task, fold, depth: int) -> None:
    """Double-buffered chunk pipeline: at most `depth` chunk tasks in flight
    beyond the one being folded, folds strictly in submission order (so any
    depth — including 0, the inline serial schedule — produces bit-identical
    reducer states).  Tasks run on one worker thread; XLA releases the GIL
    during device execution, so the main thread's reducer folds overlap the
    next chunk's compute.  Single-chunk grids run inline: there is nothing
    to overlap, and worker-thread startup would only add latency."""
    starts = list(starts)
    if depth <= 0 or len(starts) <= 1:
        for start in starts:
            fold(make_task(start)())
        return
    pending = deque()
    with ThreadPoolExecutor(max_workers=1) as ex:
        for start in starts:
            pending.append(ex.submit(make_task(start)))
            while len(pending) > depth:
                fold(pending.popleft().result())
        while pending:
            fold(pending.popleft().result())


def sweep_chunked(
    traffic,
    reducer: ChunkReducer,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
    chunk_size: int = 65536,
    shard: bool = False,
    columns_fn=None,
    materialize: str = "auto",
    prefetch: Optional[int] = None,
    **axes: Sequence[float],
):
    """Stream a configuration grid through the universal jitted chunk
    program in fixed-size chunks, folding each chunk into `reducer` and
    keeping nothing else.

    Every chunk has exactly `chunk_size` columns (the last one is padded by
    clamping the decode at the final row — repeat-last-row — then sliced
    back) so the program compiles once; peak host memory is
    O(chunk_size * n_columns), independent of grid size.  `traffic` may be
    one Traffic or a sequence (per-workload metric rows).

    `materialize` picks where chunk columns come from:
      * "device" — the jitted mixed-radix decode program generates the chunk
        from the `start` scalar and small device-resident axis tables: zero
        per-chunk host numpy, zero per-chunk H2D column transfer.
      * "host"   — `GridSpec.chunk_cols` builds the columns on the host and
        ships them (the serial reference layout; with ``shard=True`` they
        are laid out across devices along the config axis).
      * "auto"   — "device" unless sharding or a legacy `columns_fn`
        requires host columns.
    Both modes feed the same program instance, so reducer folds are
    bit-identical between them.

    `prefetch` (default: the REPRO_PREFETCH env flag, 2) chunks may be in
    flight ahead of the reducer fold; folds happen in chunk order, so every
    depth produces bit-identical reducer states.

    `columns_fn` hooks fault injection.  A scenario-carrying hook from
    `faults.faulted_columns_fn(scenario)` composes on-device: the scenario
    fields become runtime inputs of the chunk program (its numpy __call__
    stays available as the host reference).  Any other callable
    ``columns_fn(cols, topo_id, topologies) -> (nets, dev_cols)`` runs
    legacy-style on host-materialized columns, whose returned columns may
    carry a leading scenario axis ((S, chunk)).  The config-axis sharding
    path assumes 1-D columns; don't combine it with a batched `columns_fn`.
    """
    spec = grid_spec(topologies, devices=devices, **axes)
    n = spec.n
    if n == 0:
        raise ValueError("empty grid")
    _validate_grid_values(spec)

    scenario = getattr(columns_fn, "scenario", None)
    legacy_fn = columns_fn is not None and scenario is None

    if materialize not in ("auto", "host", "device"):
        raise ValueError(f"materialize must be 'auto', 'host', or 'device', "
                         f"got {materialize!r}")
    if materialize == "auto":
        materialize = "host" if (shard or legacy_fn) else "device"
    elif materialize == "device" and (shard or legacy_fn):
        # sharded layouts and legacy hooks consume host-built columns
        materialize = "host"

    depth = prefetch_depth() if prefetch is None else max(0, int(prefetch))

    sharding = _config_sharding() if shard else None
    chunk_size = int(min(max(1, chunk_size), n))
    if sharding is not None:
        ndev = len(jax.devices())
        chunk_size = ((chunk_size + ndev - 1) // ndev) * ndev

    with engine_x64():
        bits, xfers = _traffic_arrays(traffic)
        bits_j, xfers_j = _as_f64(bits), _as_f64(xfers)
        frac_j = _as_f64(active_fraction)
        scen_j = None if legacy_fn else _scenario_inputs(scenario)
        if materialize == "device":
            tables_j = {k: _as_f64(v) for k, v in spec.axes.items()}
            base_j = {k: _as_f64(v) for k, v in spec.base.items()}

    kernel = _engine_kernel(spec.topologies) if not legacy_fn \
        else _chunk_eval_kernel()
    decode = (_decode_program(spec, chunk_size)
              if materialize == "device" else None)

    def _host_chunk(start, stop):
        cols, topo_id = spec.chunk_cols(start, stop)
        pad = chunk_size - (stop - start)
        if pad:  # repeat the last (valid) row; padded lanes are sliced off
            cols = {k: np.concatenate([v, np.repeat(v[-1:], pad)])
                    for k, v in cols.items()}
            topo_id = np.concatenate([topo_id, np.repeat(topo_id[-1:], pad)])
        return cols, topo_id

    def make_task(start):
        stop = min(start + chunk_size, n)

        if legacy_fn:
            def task():
                with engine_x64():
                    cols, topo_id = _host_chunk(start, stop)
                    nets, dev_cols = columns_fn(cols, topo_id,
                                                spec.topologies)
                    nets_j = {k: _as_f64(nets[k]) for k in MODEL_FIELDS}
                    dev_j = {k: _as_f64(dev_cols[k])
                             for k in _EVAL_DEVICE_FIELDS}
                    if sharding is not None:
                        nets_j = {k: jax.device_put(v, sharding)
                                  for k, v in nets_j.items()}
                        dev_j = {k: jax.device_put(v, sharding)
                                 for k, v in dev_j.items()}
                    mets = kernel(nets_j, dev_j, bits_j, xfers_j, frac_j)
                    return start, stop, topo_id, nets, mets
            return task

        if materialize == "host":
            def task():
                with engine_x64():
                    cols, topo_id = _host_chunk(start, stop)
                    cols_j = {k: _as_f64(v) for k, v in cols.items()}
                    topo_j = jnp.asarray(topo_id)
                    if sharding is not None:
                        cols_j = {k: jax.device_put(v, sharding)
                                  for k, v in cols_j.items()}
                        topo_j = jax.device_put(topo_j, sharding)
                    nets, mets = kernel(cols_j, topo_j, scen_j,
                                        bits_j, xfers_j, frac_j)
                    return start, stop, topo_id, nets, mets
            return task

        def task():  # device-resident materialization: start scalar only
            with engine_x64():
                cols, topo_id = decode(tables_j, base_j, np.int64(start))
                nets, mets = kernel(cols, topo_id, scen_j,
                                    bits_j, xfers_j, frac_j)
                return start, stop, topo_id, nets, mets
        return task

    carry = reducer.init(spec)

    def fold(result):
        nonlocal carry
        start, stop, topo_id, nets, mets = result
        jax.block_until_ready(mets)
        valid = stop - start
        out = {k: np.asarray(v, np.float64) for k, v in mets.items()}
        out = {k: v[..., :valid] for k, v in broadcast_metrics(out, np).items()}
        nets = {k: np.asarray(v)[..., :valid] for k, v in nets.items()}
        topo_id = np.asarray(topo_id)[:valid]
        carry = reducer.step(carry, SweepChunk(
            spec=spec, start=start, stop=stop, topo_id=topo_id,
            nets=nets, metrics=out))

    _run_pipeline(range(0, n, chunk_size), make_task, fold, depth)
    return reducer.finish(carry, spec)


def sweep_scalar_reference(
    traffic: Traffic,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
    **axes: Sequence[float],
) -> Dict[str, np.ndarray]:
    """Golden reference: the identical grid walked through the scalar
    dataclass path (`NetworkParams` -> topology factory -> `evaluate_network`)
    one configuration per Python call.  Returns the same metric columns as
    `sweep(...).metrics`."""
    grid = build_grid(topologies, devices=devices, **axes)
    base = devices or DEFAULT_DEVICES
    out = {k: np.zeros(grid.n, np.float64) for k in METRIC_FIELDS}
    for i in range(grid.n):
        p = grid.row_params(i)
        d = grid.row_devices(i, base)
        name = grid.row_topology(i)
        if name == "trine":
            k = int(grid.cols["n_subnetworks"][i])
            net = TOPOLOGIES[name](p, n_subnetworks=k or None, d=d)
        else:
            net = TOPOLOGIES[name](p, d=d)
        rep = evaluate_network(net, traffic, d, active_fraction=active_fraction)
        for key in METRIC_FIELDS:
            out[key][i] = getattr(rep, key)
    return out


# --------------------------------------------------------------------------
# Batched accelerator evaluation (paper Fig. 6 path)
# --------------------------------------------------------------------------

# `evaluate_accelerator_batch` historically lived here; it is now one (mix,
# config) cell of the vmapped co-design grid kernel in core.accelerator and
# re-exported (via the import at the top) for existing callers.

"""Vectorized design-space sweep engine for the interposer-network models.

The paper's headline figures come from sweeping network configurations across
gateways / wavelengths / modulation rates / device corners.  The scalar
dataclass path (`NetworkParams` -> `NetworkModel` -> `evaluate_network`)
evaluates one configuration per Python call; this module flattens whole
parameter grids into struct-of-arrays columns and evaluates every metric the
power model produces — laser, trimming, latency, energy, energy-per-bit — for
10k+ configurations in one jitted call.

Pipeline:

  build_grid(...)          cartesian product of a topology axis, any
                           NetworkParams field, any dotted DeviceLibrary leaf
                           ("mzi.insertion_loss_db", ...), and the TRINE
                           "n_subnetworks" override -> SweepGrid of float64
                           columns.
  network_columns(grid)    struct-of-arrays NetworkModel fields, via the
                           columnar topology kernels in core.topology.
  evaluate_columns(...)    the jitted batched power/latency/energy kernel
                           (mirrors power.evaluate_network branch-free).
  sweep(traffic, ...)      all of the above in one call -> SweepResult.

`sweep_scalar_reference` walks the identical grid through the scalar
dataclass path one row at a time; it is the golden reference the parity tests
(and benchmarks/sweep_bench.py) compare the batched engine against.

`evaluate_accelerator_batch` is the same treatment for the Fig. 6 accelerator
model: all layers of a workload evaluated as one batch instead of a Python
loop per layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.devices import (
    DeviceLibrary,
    DEFAULT_DEVICES,
    device_columns,
    replace_device_leaves,
)
from repro.core.topology import (
    MODEL_FIELDS,
    PARAM_FIELDS,
    TOPOLOGIES,
    TOPOLOGY_ARRAYS,
    NetworkParams,
    model_from_row,
)
from repro.core.planner import plan_gateway_activation_arr
from repro.core.power import Traffic, evaluate_network
from repro.core.workloads import Workload
from repro.core.accelerator import (
    AccelReport,
    AcceleratorConfig,
    chiplet_columns,
    layer_columns,
)

__all__ = [
    "SweepGrid", "SweepResult", "build_grid", "network_columns",
    "evaluate_columns", "sweep", "sweep_scalar_reference",
    "evaluate_accelerator_batch", "METRIC_FIELDS", "DEFAULT_TOPOLOGIES",
]

DEFAULT_TOPOLOGIES: Tuple[str, ...] = ("sprint", "spacx", "tree", "trine", "elec")

# int-typed NetworkParams fields (scalar-reference reconstruction)
_INT_PARAM_FIELDS = frozenset({"n_gateways", "n_mem_chiplets", "n_lambda",
                               "gateway_width_bits"})

# metric columns emitted by the batched evaluator == NetworkReport fields
METRIC_FIELDS = ("power_w", "latency_s", "energy_j", "energy_per_bit_j",
                 "laser_power_w", "trimming_power_w")

# device leaves the power kernel reads (the topology kernels read the rest)
_EVAL_DEVICE_FIELDS = (
    "pd.sensitivity_dbm", "pd.energy_per_bit_j",
    "laser.power_margin_db", "laser.coupling_loss_db",
    "laser.wall_plug_efficiency", "laser.bank_overhead_w",
    "mr.tuning_power_w",
    "mzi.static_power_w", "mzi.switch_energy_j",
    "driver.energy_per_bit_j", "driver.serdes_energy_per_bit_j",
    "elec.energy_per_bit_j", "elec.router_power_w",
)


# --------------------------------------------------------------------------
# Grid construction
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A flattened cartesian parameter grid (struct-of-arrays columns).

    axis order: ("topology", *axes) — `shape` follows it, every column and
    `topo_id` is raveled to length `n = prod(shape)`.
    """

    topologies: Tuple[str, ...]
    axes: Dict[str, Tuple[float, ...]]
    cols: Dict[str, np.ndarray]
    topo_id: np.ndarray
    shape: Tuple[int, ...]

    @property
    def n(self) -> int:
        return int(self.topo_id.size)

    def row_params(self, i: int) -> NetworkParams:
        kw = {}
        for name in PARAM_FIELDS:
            v = self.cols[name][i]
            kw[name] = int(v) if name in _INT_PARAM_FIELDS else float(v)
        return NetworkParams(**kw)

    def row_devices(self, i: int,
                    base: Optional[DeviceLibrary] = None) -> DeviceLibrary:
        base = base or DEFAULT_DEVICES
        swept = {k: float(self.cols[k][i]) for k in self.axes if "." in k}
        return replace_device_leaves(base, swept) if swept else base

    def row_topology(self, i: int) -> str:
        return self.topologies[int(self.topo_id[i])]


def build_grid(
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    **axes: Sequence[float],
) -> SweepGrid:
    """Cartesian product of `topologies` x every keyword axis.

    Axis names may be NetworkParams fields (``n_gateways=(16, 32, 64)``),
    dotted DeviceLibrary leaves (``mzi.insertion_loss_db`` — pass via a dict
    expansion since dots aren't identifiers: ``**{"mzi.insertion_loss_db":
    (1.0, 2.0)}``), or ``n_subnetworks`` (TRINE K override; 0 = bandwidth-
    matched auto).  Unswept columns take their NetworkParams/DeviceLibrary
    defaults.
    """
    base: Dict[str, float] = {name: float(getattr(NetworkParams(), name))
                              for name in PARAM_FIELDS}
    base.update(device_columns(devices or DEFAULT_DEVICES))
    base["n_subnetworks"] = 0.0

    for name in axes:
        if name not in base:
            raise KeyError(
                f"unknown sweep axis {name!r}; valid axes are NetworkParams "
                f"fields, dotted device leaves, or 'n_subnetworks'")
    unknown = [t for t in topologies if t not in TOPOLOGY_ARRAYS]
    if unknown:
        raise KeyError(f"unknown topologies {unknown!r}")

    axes_vals = {k: tuple(float(x) for x in v) for k, v in axes.items()}
    shape = (len(topologies),) + tuple(len(v) for v in axes_vals.values())
    n = int(np.prod(shape))

    topo_id = np.broadcast_to(
        np.arange(len(topologies)).reshape((-1,) + (1,) * len(axes_vals)),
        shape).ravel()

    cols: Dict[str, np.ndarray] = {}
    for name, v in base.items():
        cols[name] = np.full(n, v, np.float64)
    for ai, (name, vals) in enumerate(axes_vals.items()):
        view = (1,) * (1 + ai) + (len(vals),) + (1,) * (len(axes_vals) - ai - 1)
        cols[name] = np.broadcast_to(
            np.asarray(vals, np.float64).reshape(view), shape).ravel().copy()

    return SweepGrid(topologies=tuple(topologies), axes=axes_vals,
                     cols=cols, topo_id=topo_id, shape=shape)


def network_columns(grid: SweepGrid) -> Dict[str, np.ndarray]:
    """Struct-of-arrays NetworkModel fields for every grid row."""
    out = {f: np.zeros(grid.n, np.float64) for f in MODEL_FIELDS}
    for ti, name in enumerate(grid.topologies):
        mask = grid.topo_id == ti
        sub = {k: v[mask] for k, v in grid.cols.items()}
        fields = TOPOLOGY_ARRAYS[name](sub)
        for f in MODEL_FIELDS:
            out[f][mask] = fields[f]
    return out


# --------------------------------------------------------------------------
# Batched evaluation (the jitted kernel)
# --------------------------------------------------------------------------


@jax.jit
def _eval_kernel(nets: Dict[str, jax.Array], dev: Dict[str, jax.Array],
                 total_bits: jax.Array, n_transfers: jax.Array,
                 active_fraction: jax.Array) -> Dict[str, jax.Array]:
    """Branch-free batched mirror of `power.evaluate_network`: both the
    photonic and the electrical formula evaluate on every lane, `is_electrical`
    selects.  All inputs broadcast elementwise, so callers may batch over
    configurations, workload traffics, or both at once."""
    # ---- photonic ----
    frac = jnp.clip(active_fraction, 1e-3, 1.0)
    n_lambda_active = jnp.maximum(1.0, jnp.round(nets["n_wavelengths"] * frac))
    n_banks_active = jnp.maximum(1.0, jnp.round(nets["n_laser_banks"] * frac))
    p_tx_dbm = (dev["pd.sensitivity_dbm"] + dev["laser.power_margin_db"]
                + nets["worst_path_loss_db"] + dev["laser.coupling_loss_db"])
    per_lambda_w = 1e-3 * 10.0 ** (p_tx_dbm / 10.0)
    laser_p = (n_lambda_active * per_lambda_w / dev["laser.wall_plug_efficiency"]
               + n_banks_active * dev["laser.bank_overhead_w"])
    trimming_p = nets["n_mr"] * dev["mr.tuning_power_w"] * frac
    switch_p = nets["n_mzi"] * dev["mzi.static_power_w"] * frac
    static_p = laser_p + trimming_p + switch_p

    bw = nets["effective_bw_bps"] * frac
    lat_ph = total_bits / bw + n_transfers * nets["per_transfer_s"]
    per_bit = (dev["driver.energy_per_bit_j"]
               + dev["driver.serdes_energy_per_bit_j"]
               + dev["pd.energy_per_bit_j"])
    dyn_e = total_bits * per_bit
    switch_e = n_transfers * nets["n_stages"] * dev["mzi.switch_energy_j"]
    energy_ph = static_p * lat_ph + dyn_e + switch_e
    power_ph = static_p + (dyn_e + switch_e) / jnp.maximum(lat_ph, 1e-30)

    # ---- electrical ----
    lat_el = (total_bits / nets["effective_bw_bps"]
              + n_transfers * nets["per_transfer_s"])
    dyn_el = total_bits * dev["elec.energy_per_bit_j"] * nets["avg_hops"]
    static_el = nets["n_routers"] * dev["elec.router_power_w"]
    energy_el = dyn_el + static_el * lat_el
    power_el = static_el + dyn_el / jnp.maximum(lat_el, 1e-30)

    is_el = nets["is_electrical"] > 0
    latency = jnp.where(is_el, lat_el, lat_ph)
    energy = jnp.where(is_el, energy_el, energy_ph)
    return {
        "power_w": jnp.where(is_el, power_el, power_ph),
        "latency_s": latency,
        "energy_j": energy,
        "energy_per_bit_j": energy / jnp.maximum(total_bits, 1.0),
        "laser_power_w": jnp.where(is_el, 0.0, laser_p),
        "trimming_power_w": jnp.where(is_el, 0.0, trimming_p),
    }


def _as_f64(x):
    # float64 when jax_enable_x64 is on, float32 otherwise — jnp downcasts
    return jnp.asarray(np.asarray(x, np.float64))


def evaluate_columns(
    nets: Mapping[str, np.ndarray],
    cols: Mapping[str, np.ndarray],
    total_bits,
    n_transfers,
    active_fraction=1.0,
) -> Dict[str, np.ndarray]:
    """Run the jitted batched evaluator over struct-of-arrays NetworkModel
    fields.  `total_bits` / `n_transfers` / `active_fraction` broadcast
    against the config axis (e.g. shape (W, 1) traffic x (N,) configs ->
    (W, N) metrics)."""
    nets_j = {k: _as_f64(nets[k]) for k in MODEL_FIELDS}
    dev_j = {k: _as_f64(cols[k]) for k in _EVAL_DEVICE_FIELDS}
    out = _eval_kernel(nets_j, dev_j, _as_f64(total_bits),
                       _as_f64(n_transfers), _as_f64(active_fraction))
    out = {k: np.asarray(v, np.float64) for k, v in out.items()}
    # static-only metrics (laser, trimming) don't see the traffic operands;
    # broadcast everything to the full (traffic x config) result shape
    shape = np.broadcast_shapes(*(v.shape for v in out.values()))
    return {k: np.broadcast_to(v, shape) for k, v in out.items()}


# --------------------------------------------------------------------------
# Top-level sweep API
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Metrics + model fields for every grid point (flat, length grid.n)."""

    grid: SweepGrid
    nets: Dict[str, np.ndarray]
    metrics: Dict[str, np.ndarray]

    def metric(self, name: str) -> np.ndarray:
        """One metric reshaped to the grid's (topology, *axes) shape."""
        return self.metrics[name].reshape(self.grid.shape)

    def config_at(self, i: int) -> Dict[str, float]:
        """Human-readable swept-axis settings of flat row `i`."""
        out: Dict[str, float] = {"topology": self.grid.row_topology(i)}
        for name in self.grid.axes:
            out[name] = float(self.grid.cols[name][i])
        return out

    def best(self, name: str = "energy_j") -> Tuple[int, Dict[str, float]]:
        """(flat index, swept-axis settings) of the metric's minimizer."""
        i = int(np.argmin(self.metrics[name]))
        return i, self.config_at(i)

    def model_at(self, i: int):
        """Scalar NetworkModel dataclass view of flat row `i`."""
        key = self.grid.row_topology(i)
        name = {"sprint": "SPRINT", "spacx": "SPACX", "tree": "Tree",
                "elec": "ElecMesh"}.get(key)
        if name is None:  # trine carries its subnetwork count
            name = f"TRINE-{int(self.nets['n_laser_banks'][i])}"
        return model_from_row(self.nets, name, i=i)


def sweep(
    traffic: Traffic,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
    **axes: Sequence[float],
) -> SweepResult:
    """Evaluate one workload's traffic over a full configuration grid."""
    grid = build_grid(topologies, devices=devices, **axes)
    nets = network_columns(grid)
    metrics = evaluate_columns(nets, grid.cols, traffic.total_bits,
                               traffic.n_transfers, active_fraction)
    return SweepResult(grid=grid, nets=nets, metrics=metrics)


def sweep_scalar_reference(
    traffic: Traffic,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices: Optional[DeviceLibrary] = None,
    active_fraction: float = 1.0,
    **axes: Sequence[float],
) -> Dict[str, np.ndarray]:
    """Golden reference: the identical grid walked through the scalar
    dataclass path (`NetworkParams` -> topology factory -> `evaluate_network`)
    one configuration per Python call.  Returns the same metric columns as
    `sweep(...).metrics`."""
    grid = build_grid(topologies, devices=devices, **axes)
    base = devices or DEFAULT_DEVICES
    out = {k: np.zeros(grid.n, np.float64) for k in METRIC_FIELDS}
    for i in range(grid.n):
        p = grid.row_params(i)
        d = grid.row_devices(i, base)
        name = grid.row_topology(i)
        if name == "trine":
            k = int(grid.cols["n_subnetworks"][i])
            net = TOPOLOGIES[name](p, n_subnetworks=k or None, d=d)
        else:
            net = TOPOLOGIES[name](p, d=d)
        rep = evaluate_network(net, traffic, d, active_fraction=active_fraction)
        for key in METRIC_FIELDS:
            out[key][i] = getattr(rep, key)
    return out


# --------------------------------------------------------------------------
# Batched accelerator evaluation (paper Fig. 6 path, one batch per workload)
# --------------------------------------------------------------------------


def evaluate_accelerator_batch(
    accel: AcceleratorConfig,
    wl: Workload,
    devices: Optional[DeviceLibrary] = None,
) -> AccelReport:
    """Batched mirror of `accelerator.evaluate_accelerator`: the per-layer
    Python loop becomes struct-of-arrays math over all layers at once, with
    the network evaluated through the shared jitted kernel."""
    d = devices or DEFAULT_DEVICES
    lc = layer_columns(wl)
    cc = chiplet_columns(accel)

    # compute: layer split across chiplets by throughput for its dot length
    passes = np.ceil(lc["dot_length"][:, None] / cc["vector_size"][None, :])
    thr = cc["n_units"][None, :] * accel.mac_rate_hz / passes
    total_thr = thr.sum(axis=1)
    slots_best = (passes * cc["vector_size"][None, :]).min(axis=1)
    c_s = lc["n_dots"] / total_thr
    compute_energy = float(
        (lc["n_dots"] * slots_best).sum() * accel.lambda_slot_energy_j)

    bytes_total = lc["weight_bytes"] + lc["in_bytes"] + lc["out_bytes"]
    total_bits = 8.0 * bytes_total
    n_transfers = np.full_like(bytes_total, accel.transfers_per_layer)

    net = accel.network
    if accel.adaptive_gateways:
        demand = bytes_total / np.maximum(c_s, 1e-12)
        frac = plan_gateway_activation_arr(
            demand, net.effective_bw_bps / 8.0,
            max(1, net.n_wavelengths // 8))
    else:
        frac = np.ones_like(bytes_total)

    nets = {f: np.float64(getattr(net, f)) for f in MODEL_FIELDS}
    rep = evaluate_columns(nets, device_columns(d), total_bits, n_transfers,
                           frac)

    mem_s = bytes_total / accel.mem_bw_bytes_per_s
    # double-buffered: network/memory overlap compute; layer pays the max
    layer_lat = np.maximum(np.maximum(c_s, rep["latency_s"]), mem_s)
    total_lat = float(layer_lat.sum())
    net_energy = float(rep["energy_j"].sum())
    bits_sum = float(total_bits.sum())
    energy = compute_energy + net_energy
    return AccelReport(
        name=accel.name,
        latency_s=total_lat,
        power_w=energy / max(total_lat, 1e-30),
        energy_j=energy,
        epb_j=net_energy / max(bits_sum, 1.0),
        compute_s=float(c_s.sum()),
        network_s=float(rep["latency_s"].sum()),
        memory_s=float(mem_s.sum()),
        network_energy_j=net_energy,
    )

"""Pareto/co-design search engine on top of the batched sweep engine.

The paper's value proposition is a design-space argument: find the
interposer-network (and chiplet-mix) configurations on the latency / energy /
power frontier.  `core.sweep` evaluates grids; this module extracts and
refines frontiers:

  pareto_mask(points)        jitted O(n log n) Pareto-front membership for
                             2- or 3-objective point clouds — lexicographic
                             sort + linear scan with a Fenwick (binary
                             indexed) prefix-min tree over second-objective
                             ranks, NOT the O(n^2) pairwise mask.  Exact:
                             objectives are dense-rank transformed first, so
                             float32 tracing cannot flip a dominance
                             comparison (ranks < 2^24 are exact in f32).
  pareto_mask_reference      the O(n^2) blockwise numpy brute force the
                             tests/benchmarks cross-check against.
  ParetoFront / merge_fronts streaming-compatible front objects: Pareto
                             extraction distributes over unions,
                             front(A ∪ B) = front(front(A) ∪ front(B)),
                             so per-chunk fronts merge into the exact
                             whole-grid front.
  ParetoReducer              a `core.sweep.ChunkReducer` — plugs the merge
                             reduction into `sweep_chunked`, holding only the
                             running front (bounded memory for 1e7-point
                             grids).
  pareto_search(...)         one-call streaming per-workload front over a
                             network grid.
  codesign_pareto(...)       the joint network × chiplet-mix search: each
                             grid chunk is evaluated through the vmapped
                             accelerator kernel (`core.accelerator.
                             evaluate_accelerator_grid`), flat indices encode
                             (mix, network-config).
  refine_continuous(...)     gradient-based local refinement: jax.grad
                             through the xp-generic topology kernels + the
                             shared metric math w.r.t. the *continuous*
                             columns (losses, rates, bandwidths, geometry),
                             descended with a projected (log-space, boxed)
                             gradient loop from a Pareto point.
  refine_codesign(...)       the co-design analog: joint relaxed descent
                             over accelerator axes (per-chiplet n_units /
                             vector_size, mac_rate_hz, lambda_slot_energy_j)
                             AND network axes, seeded from a codesign_pareto
                             frontier row, then round-and-rescore — snap the
                             discrete axes to integer neighbors and exactly
                             re-score every candidate through the grid
                             kernel, so the reported point is always a
                             feasible integer design, never worse than its
                             seed.  Accepts one Workload or a weighted batch
                             (scalarized as the weighted geomean of the
                             per-workload objective) and two descent
                             methods: "first_order" (fixed-lr projected
                             gradient + one-shot floor/ceil snap) and
                             "trust_region" (second-order log-space
                             trust-region descent + coordinate-wise integer
                             line search to a local integer optimum).
  refine_trust_region(...)   `refine_codesign(method="trust_region")`: the
                             second-order multi-workload engine in one call.
  refine_front(...)          frontier-wide driver: refine every (or top-k)
                             row, merge the refined points back with
                             merge_fronts (the result weakly dominates the
                             seed front by construction — asserted), report
                             per-axis gradient-magnitude sensitivities.

Dominance convention (weak Pareto): point q dominates p iff q <= p in every
objective and q != p in at least one; exact duplicates do not dominate each
other, so all copies of a non-dominated point stay on the front.  Lower is
better in every objective.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.env import prefetch_depth
from repro.core.power import (
    EVAL_DEVICE_FIELDS,
    Traffic,
    engine_x64,
    eval_network_math,
)
from repro.core.topology import MODEL_FIELDS, TOPOLOGY_ARRAYS
from repro.core.sweep import (
    DEFAULT_TOPOLOGIES,
    INTEGER_AXES,
    METRIC_FIELDS,
    ChunkReducer,
    GridSpec,
    SweepChunk,
    SweepResult,
    _as_f64,
    _decode_program,
    _nets_program,
    _network_columns_arrays,
    _run_pipeline,
    _validate_grid_values,
    grid_spec,
    sweep_chunked,
)
from repro.core.workloads import Workload

__all__ = [
    "OBJECTIVES", "pareto_mask", "pareto_mask_reference", "ParetoFront",
    "merge_fronts", "pareto_front", "ParetoReducer", "pareto_search",
    "codesign_pareto", "codesign_config_at", "frontier_configs",
    "refine_continuous", "refine_front_point", "DEFAULT_REFINE_AXES",
    "refine_codesign", "refine_trust_region", "refine_front",
    "ACCEL_REFINE_AXES",
]

# the paper's three reported quantities, all minimized
OBJECTIVES: Tuple[str, ...] = ("latency_s", "energy_j", "power_w")


# --------------------------------------------------------------------------
# Jitted O(n log n) front extraction (sort + scan)
# --------------------------------------------------------------------------


def _pareto2_scan(f: jax.Array) -> jax.Array:
    """Dominated mask for lex-sorted deduplicate-representative 2D points:
    i is dominated iff some strictly-earlier row has f1 <= f1[i] (f0 <= is
    implied by the sort) — an exclusive prefix cummin + compare."""
    n = f.shape[0]
    excl = jnp.concatenate([
        jnp.full((1,), jnp.inf, f.dtype), lax.cummin(f[:, 1])[:-1]])
    return excl <= f[:, 1]


def _pareto3_scan(f: jax.Array) -> jax.Array:
    """Dominated mask for lex-sorted 3D points via a Fenwick prefix-min tree.

    After sorting by (f0, f1, f2), row i is dominated iff an earlier row has
    f1 <= f1[i] AND f2 <= f2[i].  Scanning rows in sorted order while
    maintaining a Fenwick tree over f1-ranks holding the min f2 inserted so
    far answers that prefix query in O(log n); total O(n log n) — the
    Kung–Luccio–Preparata sweep expressed as a lax.scan."""
    n = f.shape[0]
    log_n = max(1, int(np.ceil(np.log2(n + 1))) + 1)  # static trip count
    sorted_f1 = jnp.sort(f[:, 1])
    # rank(v) = #elements < v: ties share a rank, so "rank <= r[i]" covers
    # exactly the f1 <= f1[i] population.  1-indexed for the Fenwick tree.
    r = (jnp.searchsorted(sorted_f1, f[:, 1], side="left")
         .astype(jnp.int32) + 1)
    tree0 = jnp.full((n + 1,), jnp.inf, f.dtype)

    def step(tree, rz):
        ri, zi = rz

        def qbody(_, mp):  # prefix-min query over ranks [1, ri]
            m, p = mp
            m = jnp.minimum(m, jnp.where(p > 0, tree[p], jnp.inf))
            return m, p - (p & -p)

        m, _ = lax.fori_loop(
            0, log_n, qbody, (jnp.asarray(jnp.inf, f.dtype), ri))
        dominated = m <= zi

        def ubody(_, tp):  # point update: tree[p] = min(tree[p], zi) upward
            t, p = tp
            ok = p <= n
            idx = jnp.where(ok, p, 0)
            t = t.at[idx].min(jnp.where(ok, zi, jnp.inf))
            return t, jnp.where(ok, p + (p & -p), p)

        tree, _ = lax.fori_loop(0, log_n, ubody, (tree, ri))
        return tree, dominated

    _, dominated = lax.scan(step, tree0, (r, f[:, 2]))
    return dominated


def _pareto_mask_core(pts: jax.Array) -> jax.Array:
    """(n, m) points -> (n,) front-membership mask.  m in {2, 3} (static)."""
    n, m = pts.shape
    order = jnp.lexsort(tuple(pts[:, j] for j in range(m - 1, -1, -1)))
    f = pts[order]
    # exact duplicates never dominate each other: every row of a duplicate
    # run takes the verdict of its first row (the representative), whose
    # prefix query sees only strictly-earlier distinct rows
    eq_prev = jnp.concatenate([
        jnp.zeros((1,), bool), jnp.all(f[1:] == f[:-1], axis=1)])
    rep = lax.cummax(jnp.where(eq_prev, -1, jnp.arange(n)))
    dominated = (_pareto2_scan(f) if m == 2 else _pareto3_scan(f))[rep]
    return jnp.zeros((n,), bool).at[order].set(~dominated)


_pareto_mask_jit = jax.jit(_pareto_mask_core)

_MAX_POINTS = 1 << 24  # dense ranks stay exact in float32 below this


def _padded_size(n: int) -> int:
    return max(16, 1 << (n - 1).bit_length())


def pareto_mask(points) -> np.ndarray:
    """Front membership (lower-is-better weak dominance) of an (n, m) point
    cloud, m in {2, 3}, via the jitted sort+scan extractor.

    Inputs are dense-rank transformed per objective before tracing, so the
    result is exact float64 dominance regardless of the jax default dtype;
    +inf rows (used internally for padding) always land off the front when
    any finite point exists.  Inputs are padded to the next power of two so
    the jit cache stays O(log n) entries across chunk/merge call sites.
    """
    pts = np.asarray(points, np.float64)
    if pts.ndim != 2 or pts.shape[1] not in (2, 3):
        raise ValueError(f"expected (n, 2|3) points, got shape {pts.shape}")
    n = pts.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    if n >= _MAX_POINTS:
        raise ValueError(
            f"pareto_mask handles < {_MAX_POINTS} points per call; stream "
            "larger grids through ParetoReducer / pareto_search")
    npad = _padded_size(n)
    if npad != n:
        pts = np.concatenate(
            [pts, np.full((npad - n, pts.shape[1]), np.inf)], axis=0)
    ranks = np.empty(pts.shape, np.float32)
    for j in range(pts.shape[1]):
        _, inv = np.unique(pts[:, j], return_inverse=True)
        ranks[:, j] = inv
    return np.asarray(_pareto_mask_jit(jnp.asarray(ranks)))[:n]


def pareto_mask_reference(points, block: int = 2048) -> np.ndarray:
    """O(n^2) blockwise pairwise-dominance brute force (numpy float64): the
    golden reference `pareto_mask` is tested and benchmarked against."""
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    dominated = np.zeros(n, bool)
    for s in range(0, n, block):
        p = pts[s:s + block]
        dom = np.zeros(p.shape[0], bool)
        for s2 in range(0, n, block):
            q = pts[s2:s2 + block]
            le = (q[:, None, :] <= p[None, :, :]).all(-1)
            ne = (q[:, None, :] != p[None, :, :]).any(-1)
            dom |= (le & ne).any(0)
        dominated[s:s + block] = dom
    return ~dominated


# --------------------------------------------------------------------------
# Front objects + the merge-fronts reduction
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParetoFront:
    """A set of mutually non-dominated points with their flat design indices
    (grid rows; for co-design searches, mix_id * grid_n + grid row)."""

    objectives: Tuple[str, ...]
    points: np.ndarray   # (k, m) float64 objective values
    indices: np.ndarray  # (k,) int64

    @property
    def size(self) -> int:
        return int(self.indices.size)

    def canonical(self) -> "ParetoFront":
        """Deterministic ordering (lex by objectives, then index) so fronts
        from different evaluation orders compare with array_equal."""
        keys = (self.indices,) + tuple(
            self.points[:, j] for j in range(self.points.shape[1] - 1, -1, -1))
        order = np.lexsort(keys)
        return ParetoFront(self.objectives, self.points[order],
                           self.indices[order])

    def configs(self, spec: GridSpec) -> List[Dict[str, float]]:
        return [spec.config_at(int(i)) for i in self.indices]


def _front_exact(points: np.ndarray, indices: np.ndarray,
                 objectives: Tuple[str, ...]) -> ParetoFront:
    mask = pareto_mask(points)
    return ParetoFront(objectives, points[mask],
                       np.asarray(indices)[mask].astype(np.int64)).canonical()


def _dominated_by(pts: np.ndarray, front_pts: np.ndarray) -> np.ndarray:
    """Which of `pts` are weakly dominated by some member of `front_pts`
    (numpy, blockwise) — the cheap prefilter before exact merge."""
    n = pts.shape[0]
    if front_pts.size == 0 or n == 0:
        return np.zeros(n, bool)
    out = np.zeros(n, bool)
    block = max(256, 8_000_000 // max(1, front_pts.shape[0]))
    for s in range(0, n, block):
        p = pts[s:s + block]
        le = (front_pts[None, :, :] <= p[:, None, :]).all(-1)
        ne = (front_pts[None, :, :] != p[:, None, :]).any(-1)
        out[s:s + block] = (le & ne).any(1)
    return out


_FRONT_BLOCK = 4096


def _front_of(points: np.ndarray, indices: np.ndarray,
              objectives: Tuple[str, ...],
              block: int = _FRONT_BLOCK) -> ParetoFront:
    """Exact front of an arbitrary point cloud.  Large clouds are folded
    block-by-block: each block is prefiltered against the running front
    (cheap vectorized numpy dominance, O(block * front_size)), and only the
    survivors go through the exact jitted sort+scan — so the sequential scan
    never sees more than front_size + block points at once.  A dominated
    point is always dominated by some *front* member (dominance is
    transitive), so prefiltering against the running front of everything
    seen so far is lossless."""
    indices = np.asarray(indices).astype(np.int64)
    n = points.shape[0]
    if n <= block:
        return _front_exact(points, indices, objectives)
    front: Optional[ParetoFront] = None
    for s in range(0, n, block):
        pts_b, idx_b = points[s:s + block], indices[s:s + block]
        if front is not None and front.size:
            keep = ~_dominated_by(pts_b, front.points)
            pts_b = np.concatenate([front.points, pts_b[keep]], axis=0)
            idx_b = np.concatenate([front.indices, idx_b[keep]], axis=0)
        front = _front_exact(pts_b, idx_b, objectives)
    return front


def merge_fronts(*fronts: ParetoFront) -> ParetoFront:
    """front(A ∪ B ∪ ...) from per-part fronts: Pareto extraction distributes
    over unions, which is what makes chunked streaming search exact."""
    if not fronts:
        raise ValueError("no fronts to merge")
    objectives = fronts[0].objectives
    if any(f.objectives != objectives for f in fronts):
        raise ValueError("fronts disagree on objectives")
    pts = np.concatenate([f.points for f in fronts], axis=0)
    idx = np.concatenate([f.indices for f in fronts], axis=0)
    return _front_of(pts, idx, objectives)


def _merge_into(front: Optional[ParetoFront], pts: np.ndarray,
                idx: np.ndarray,
                objectives: Tuple[str, ...]) -> ParetoFront:
    """Merge a raw point block into a running front: prefilter points the
    front already dominates, then extract over front + survivors."""
    idx = np.asarray(idx).astype(np.int64)
    if front is not None and front.size:
        keep = ~_dominated_by(pts, front.points)
        pts = np.concatenate([front.points, pts[keep]], axis=0)
        idx = np.concatenate([front.indices, idx[keep]], axis=0)
    return _front_of(pts, idx, objectives)


def pareto_front(result: SweepResult,
                 objectives: Sequence[str] = OBJECTIVES):
    """Monolithic front(s) of an in-memory SweepResult: one ParetoFront, or
    a list of them when the sweep batched multiple workload traffics."""
    objectives = tuple(objectives)
    pts = np.stack([np.asarray(result.metrics[k], np.float64)
                    for k in objectives], axis=-1)
    idx = np.arange(pts.shape[-2])
    if pts.ndim == 2:
        return _front_of(pts, idx, objectives)
    return [_front_of(pts[w], idx, objectives) for w in range(pts.shape[0])]


class ParetoReducer(ChunkReducer):
    """`sweep_chunked` reducer holding only the running per-workload
    front(s): the bounded-memory streaming Pareto search."""

    def __init__(self, objectives: Sequence[str] = OBJECTIVES):
        self.objectives = tuple(objectives)

    def step(self, carry, chunk: SweepChunk):
        pts_all = np.stack([np.asarray(chunk.metrics[k], np.float64)
                            for k in self.objectives], axis=-1)
        scalar = pts_all.ndim == 2
        blocks = [pts_all] if scalar else list(pts_all)
        if carry is None:
            carry = {"scalar": scalar, "fronts": [None] * len(blocks)}
        idx = chunk.indices
        carry["fronts"] = [
            _merge_into(front, pts, idx, self.objectives)
            for front, pts in zip(carry["fronts"], blocks)]
        return carry

    def finish(self, carry, spec: GridSpec):
        if carry is None:
            raise ValueError("empty sweep")
        return carry["fronts"][0] if carry["scalar"] else carry["fronts"]


def pareto_search(
    traffic,
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices=None,
    active_fraction: float = 1.0,
    chunk_size: int = 65536,
    objectives: Sequence[str] = OBJECTIVES,
    shard: bool = False,
    columns_fn=None,
    materialize: str = "auto",
    prefetch: Optional[int] = None,
    **axes: Sequence[float],
):
    """Streaming per-workload Pareto front over a network configuration grid:
    `sweep_chunked` + `ParetoReducer` in one call.  Returns a ParetoFront
    (or a list per workload traffic); recover configurations with
    `front.configs(grid_spec(topologies, **axes))`.

    `columns_fn` passes through to `sweep_chunked` — with
    `core.faults.faulted_columns_fn(scenario)` the result is the *survivable*
    frontier: the Pareto front of the grid as it performs under the fault
    scenario rather than healthy.  `materialize` / `prefetch` likewise pass
    through (device-resident decode + prefetch pipeline by default); front
    merges happen in chunk order, so every mode/depth yields the identical
    front."""
    return sweep_chunked(
        traffic, ParetoReducer(objectives), topologies=topologies,
        devices=devices, active_fraction=active_fraction,
        chunk_size=chunk_size, shard=shard, columns_fn=columns_fn,
        materialize=materialize, prefetch=prefetch, **axes)


# --------------------------------------------------------------------------
# Co-design search: network grid x chiplet-mix axis
# --------------------------------------------------------------------------


ACCEL_OBJECTIVES: Tuple[str, ...] = ("latency_s", "energy_j", "power_w")


def codesign_pareto(
    wl: Workload,
    mixes: Sequence[Sequence],
    topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
    devices=None,
    chunk_size: int = 8192,
    objectives: Sequence[str] = ACCEL_OBJECTIVES,
    mac_rate_hz: float = 5e9,
    lambda_slot_energy_j: float = 30e-15,
    adaptive_gateways: bool = True,
    transfers_per_layer: int = 16,
    materialize: str = "auto",
    prefetch: Optional[int] = None,
    **axes: Sequence[float],
) -> Tuple[ParetoFront, GridSpec]:
    """Joint (network-grid x chiplet-mix) Pareto search for one workload.

    Streams the network grid in chunks; each chunk is evaluated against all
    `mixes` at once through the vmapped accelerator kernel
    (`core.accelerator.evaluate_accelerator_grid`), and the running front is
    merged per chunk.  Flat front indices encode the joint design point as
    ``mix_id * spec.n + grid_row`` — decode with `codesign_config_at`.
    Memory is O(len(mixes) * chunk_size * n_layers), independent of grid
    size.

    Chunk columns and network fields stay device-resident end to end by
    default (``materialize="device"``: the jitted mixed-radix decode + the
    traced network-column builder + the accelerator kernel, no per-chunk
    host numpy); ``materialize="host"`` is the serial reference layout
    (`GridSpec.chunk_cols` on the host, shipped to the device).  Both modes
    route through the SAME traced network-column program, so their fronts
    are bit-identical.  `prefetch` chunks (default: REPRO_PREFETCH, 2) run
    ahead of the front merge; merges happen in chunk order, so every depth
    yields the identical front.
    """
    from repro.core.accelerator import (
        chiplet_mix_columns,
        evaluate_accelerator_grid,
    )

    objectives = tuple(objectives)
    if not mixes:
        raise ValueError("empty mixes: need at least one chiplet mix")
    spec = grid_spec(topologies, devices=devices, **axes)
    n = spec.n
    if n == 0:
        raise ValueError(
            "empty grid: every swept axis (and `topologies`) needs at "
            "least one value")
    _validate_grid_values(spec)
    chiplet_mix_columns(mixes)  # eager validation (tasks run on a worker)
    if materialize not in ("auto", "host", "device"):
        raise ValueError(f"materialize must be 'auto', 'host', or 'device', "
                         f"got {materialize!r}")
    if materialize == "auto":
        materialize = "device"
    depth = prefetch_depth() if prefetch is None else max(0, int(prefetch))

    n_mix = len(mixes)
    mix_off = np.arange(n_mix, dtype=np.int64)[:, None] * n
    step = int(min(max(1, chunk_size), n))
    nets_prog = _nets_program(spec.topologies)
    decode = _decode_program(spec, step) if materialize == "device" else None
    if decode is not None:
        with engine_x64():
            tables_j = {k: _as_f64(v) for k, v in spec.axes.items()}
            base_j = {k: _as_f64(v) for k, v in spec.base.items()}

    def make_task(start):
        stop = min(start + step, n)

        def task():
            with engine_x64():
                if decode is not None:
                    cols, topo_id = decode(tables_j, base_j, np.int64(start))
                else:
                    cols, topo_id = spec.chunk_cols(start, stop)
                    pad = step - (stop - start)
                    if pad:  # repeat the last row so the kernel compiles
                        # once; padded lanes are sliced off at the fold
                        cols = {k: np.concatenate([v, np.repeat(v[-1:], pad)])
                                for k, v in cols.items()}
                        topo_id = np.concatenate(
                            [topo_id, np.repeat(topo_id[-1:], pad)])
                    cols = {k: _as_f64(v) for k, v in cols.items()}
                    topo_id = jnp.asarray(topo_id)
                nets, mem_bw = nets_prog(cols, topo_id)
                out = evaluate_accelerator_grid(
                    wl, mixes, nets, cols, mem_bw,
                    mac_rate_hz=mac_rate_hz,
                    lambda_slot_energy_j=lambda_slot_energy_j,
                    adaptive_gateways=adaptive_gateways,
                    transfers_per_layer=transfers_per_layer,
                    as_numpy=False)
                return start, stop, out
        return task

    front: Optional[ParetoFront] = None

    def fold(result):
        nonlocal front
        start, stop, out = result
        jax.block_until_ready(out)
        valid = stop - start
        pts = np.stack(
            [np.asarray(out[k], np.float64)[:, :valid] for k in objectives],
            axis=-1).reshape(n_mix * valid, len(objectives))
        idx = (mix_off + np.arange(start, stop)[None, :]).reshape(-1)
        front = _merge_into(front, pts, idx, objectives)

    _run_pipeline(range(0, n, step), make_task, fold, depth)
    assert front is not None  # n > 0 and n_mix > 0 guarantee >= 1 chunk
    return front, spec


def codesign_config_at(spec: GridSpec, mixes: Sequence, flat_index: int
                       ) -> Dict[str, object]:
    """Decode a `codesign_pareto` flat index into mix + network settings."""
    flat_index = int(flat_index)
    mix_id, row = divmod(flat_index, spec.n)
    out: Dict[str, object] = {"mix": mix_id, "chiplets": list(mixes[mix_id])}
    out.update(spec.config_at(row))
    return out


def frontier_configs(front: ParetoFront, spec: GridSpec,
                     mixes: Optional[Sequence] = None
                     ) -> List[Dict[str, object]]:
    """Decode every frontier row of `front` into a config dict, in the
    front's canonical order.  Pass `mixes` for co-design fronts (flat index
    = mix_id * spec.n + grid_row -> dict with "mix"/"chiplets" keys); omit
    it for plain network fronts (flat index = grid row).  The dicts are
    directly consumable by `core.fabric.Fabric.from_config`."""
    if mixes is not None:
        return [codesign_config_at(spec, mixes, int(i))
                for i in front.indices]
    return front.configs(spec)


# --------------------------------------------------------------------------
# Gradient refinement of Pareto points (projected descent, log-space)
# --------------------------------------------------------------------------


DEFAULT_REFINE_AXES: Tuple[str, ...] = (
    "modulation_rate_bps", "mem_bw_bytes_per_s", "interposer_side_cm",
    "mzi.insertion_loss_db")


def _check_objective(objective: str, vocabulary: Sequence[str],
                     where: str) -> None:
    """Eager objective-name validation: fail with the valid vocabulary
    before any tracing happens (a bare KeyError surfacing from deep inside
    a jitted loss names no valid options and wastes the compile)."""
    if objective != "edp" and objective not in vocabulary:
        raise ValueError(
            f"unknown {where} objective {objective!r}; valid objectives "
            f"are 'edp' or one of {list(vocabulary)}")


def _projected_descent(value_and_grad, theta0, lo, hi, steps: int,
                       lr: float):
    """Log-space projected gradient descent shared by the refiners:
    theta <- clip(theta - lr * grad, lo, hi), tracking the best iterate
    ever visited (the trajectory is not monotone across quantization
    boundaries).  Returns (best_loss, best_theta, trace, grad0) where
    grad0 is the float64 gradient at theta0 — the per-axis sensitivity
    `refine_codesign` reports."""
    theta = theta0
    best_loss, best_theta = np.inf, theta
    trace: List[float] = []
    grad0: Optional[np.ndarray] = None
    for _ in range(steps):
        v, g = value_and_grad(theta)
        if grad0 is None:
            grad0 = np.asarray(g, np.float64)
        v = float(v)
        trace.append(v)
        if v < best_loss:
            best_loss, best_theta = v, theta
        theta = jnp.clip(theta - lr * g, lo, hi)
    v_end = float(value_and_grad(theta)[0])
    trace.append(v_end)
    if v_end < best_loss:
        best_loss, best_theta = v_end, theta
    if grad0 is None:  # steps == 0: report a zero sensitivity vector
        grad0 = np.zeros(np.shape(theta0), np.float64)
    return best_loss, best_theta, trace, grad0


def _tr_step(hess: np.ndarray, grad: np.ndarray, radius: float,
             damping: float = 1e-6) -> np.ndarray:
    """Approximately solve the trust-region subproblem
    min_s g.s + 0.5 s.H.s  s.t.  |s| <= radius  by Levenberg damping:
    symmetrize H, eigendecompose, lift the spectrum so the smallest
    eigenvalue is at least `damping` (negative curvature becomes a
    steepest-descent-like direction instead of a runaway), then escalate
    the ridge until the damped Newton step fits inside the radius.  Any
    non-finite curvature falls back to the radius-length steepest-descent
    step, so the caller always gets a usable direction."""
    g = np.asarray(grad, np.float64)

    def _cauchy():
        n = float(np.linalg.norm(g))
        return -g * (radius / n) if n > 0 else np.zeros_like(g)

    H = np.asarray(hess, np.float64)
    H = 0.5 * (H + H.T)
    if not np.all(np.isfinite(H)):
        return _cauchy()
    w, V = np.linalg.eigh(H)
    lam = max(0.0, damping - float(w.min()))
    gp = V.T @ g
    s = np.zeros_like(g)
    for _ in range(64):
        s = -(V @ (gp / (w + lam)))
        norm = float(np.linalg.norm(s))
        if not np.isfinite(norm):
            return _cauchy()
        if norm <= radius:
            break
        lam = 2.0 * lam + damping
    norm = float(np.linalg.norm(s))
    if not np.isfinite(norm) or norm == 0.0:
        return _cauchy()
    if norm > radius:
        s *= radius / norm
    return s


def _trust_region_descent(value_and_grad, hess_fn, theta0, lo, hi,
                          steps: int, radius: float = 0.5,
                          min_radius: float = 1e-5,
                          max_radius: float = 4.0,
                          accept_ratio: float = 1e-4,
                          damping: float = 1e-6):
    """Box-constrained trust-region descent — the second-order alternative
    to `_projected_descent`, shared by `refine_codesign(method=
    "trust_region")` and directly unit-testable with plain-python callables.

    Each iteration builds the local quadratic model from the exact gradient
    and Hessian of the objective (`hess_fn`), solves the subproblem via
    `_tr_step`, clips the candidate into the [lo, hi] box, and
    accepts/rejects on an exact re-evaluation at the clipped candidate:
    rho = actual_decrease / model_decrease.  Accepted steps with good model
    agreement while pinned at the radius grow the radius (x2, capped at
    `max_radius`); rejected or badly-modelled steps shrink it (x0.25); the
    loop stops early once the radius collapses below `min_radius` or the
    box pins the iterate.  The best iterate ever visited is returned, so
    the result is never worse than theta0.

    Everything runs host-side in float64; `value_and_grad`/`hess_fn` may be
    jitted jax callables or plain functions.  Returns (best_loss,
    best_theta, trace, grad0, stats): `trace` is the accepted-iterate loss
    history (trace[0] is the seed loss), `grad0` the float64 gradient at
    theta0, and `stats` counts accepts/rejects and records the
    per-iteration radius trajectory (an entry AFTER each update — a
    rejected step shows a strictly smaller radius than its predecessor)."""
    theta = np.clip(np.asarray(theta0, np.float64),
                    np.asarray(lo, np.float64), np.asarray(hi, np.float64))
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    v, g = value_and_grad(theta)
    f = float(v)
    g = np.asarray(g, np.float64)
    grad0 = g.copy()
    best_loss, best_theta = f, theta.copy()
    trace: List[float] = [f]
    stats: Dict[str, object] = {
        "accepted": 0, "rejected": 0, "radius_trace": [],
        "stopped_early": False}
    radius = float(radius)
    for _ in range(int(steps)):
        H = np.asarray(hess_fn(theta), np.float64)
        s = _tr_step(H, g, radius, damping)
        cand = np.clip(theta + s, lo, hi)
        s_eff = cand - theta
        if not np.any(s_eff):
            stats["stopped_early"] = True
            break  # pinned against the box: no admissible move left
        pred = -(float(g @ s_eff) + 0.5 * float(s_eff @ H @ s_eff))
        v_new, g_new = value_and_grad(cand)
        f_new = float(v_new)
        actual = f - f_new
        if pred > 0:
            rho = actual / pred
        else:  # model predicts no decrease: trust the exact re-score alone
            rho = np.inf if actual > 0 else -np.inf
        if np.isfinite(f_new) and actual > 0 and rho >= accept_ratio:
            theta, f = cand, f_new
            g = np.asarray(g_new, np.float64)
            trace.append(f)
            stats["accepted"] = int(stats["accepted"]) + 1
            if f < best_loss:
                best_loss, best_theta = f, theta.copy()
            if rho > 0.75 and float(np.linalg.norm(s_eff)) >= 0.8 * radius:
                radius = min(2.0 * radius, max_radius)
        else:
            stats["rejected"] = int(stats["rejected"]) + 1
            radius *= 0.25
        stats["radius_trace"].append(radius)
        if radius < min_radius:
            stats["stopped_early"] = True
            break
    stats["final_radius"] = radius
    return best_loss, best_theta, trace, grad0, stats


def _coordinate_int_search(x0: Mapping, lo: Mapping, hi: Mapping, score,
                           max_sweeps: int = 4, max_steps: int = 64):
    """Coordinate-wise integer line search: walk each discrete axis in ±1
    integer steps holding the others fixed, keeping every strictly
    improving move and continuing in the improving direction; sweep the
    axes round-robin until one full sweep makes no move (a local integer
    optimum) or `max_sweeps` is exhausted.  `score(values) -> float` must
    return +inf (or raise nothing) for infeasible candidates; scores are
    memoized so a design is never re-scored.  Seeded at `x0` (assumed
    feasible — e.g. the floor/ceil snap winner), so the result is never
    worse than its seed.  Returns (best_values, best_score, stats)."""
    cur = {k: int(v) for k, v in x0.items()}
    keys = list(cur)
    cache: Dict[Tuple[int, ...], float] = {}

    def _scored(vals: Mapping) -> float:
        key = tuple(int(vals[k]) for k in keys)
        if key not in cache:
            cache[key] = float(score(vals))
        return cache[key]

    cur_v = _scored(cur)
    sweeps = 0
    for _ in range(int(max_sweeps)):
        sweeps += 1
        moved = False
        for k in keys:
            for d in (+1, -1):
                for _step in range(int(max_steps)):
                    cand = dict(cur)
                    cand[k] = cur[k] + d
                    if not (int(lo[k]) <= cand[k] <= int(hi[k])):
                        break
                    v = _scored(cand)
                    if v < cur_v:
                        cur, cur_v = cand, v
                        moved = True
                    else:
                        break
        if not moved:
            break
    return cur, cur_v, {"n_scored": len(cache), "n_sweeps": sweeps}


def refine_continuous(
    topology: str,
    overrides: Mapping[str, float],
    traffic: Traffic,
    refine_axes: Sequence[str] = DEFAULT_REFINE_AXES,
    objective: str = "edp",
    steps: int = 48,
    lr: float = 0.1,
    span: float = 4.0,
    bounds: Optional[Mapping[str, Tuple[float, float]]] = None,
    active_fraction: float = 1.0,
    devices=None,
) -> Dict[str, object]:
    """Locally refine one configuration by jax.grad through the continuous
    columns (losses, rates, bandwidths, interposer geometry).

    The design point is parameterized in log-space (every continuous column
    is positive and spans decades) and descended with a projected-gradient
    loop: theta <- clip(theta - lr * grad, log lo, log hi), default box
    [x0/span, x0*span] per axis.  `objective` is "edp"
    (log energy + log latency, the example's search quantity) or any metric
    name ("energy_j", "latency_s", "power_w", ...) minimized in log-space.
    Discrete kernel quantities (stage counts, subnetwork counts, rounded
    active-wavelength counts) are piecewise-constant — zero gradient — so
    descent moves only along genuinely continuous directions; a step that
    crosses a quantization boundary is still scored exactly by the next
    forward evaluation.

    Returns {"start", "refined"} column values, the objective trace, and the
    refined point's full metric dict.
    """
    if topology not in TOPOLOGY_ARRAYS:
        raise KeyError(f"unknown topology {topology!r}")
    _check_objective(objective, METRIC_FIELDS, "refine_continuous")
    spec = grid_spec((topology,), devices=devices)
    cols: Dict[str, float] = dict(spec.base)
    for k, v in overrides.items():
        if k == "topology":
            continue
        if k not in cols:
            raise KeyError(f"unknown column {k!r}")
        cols[k] = float(v)
    names = tuple(refine_axes)
    for nm in names:
        if nm not in cols:
            raise KeyError(f"unknown refine axis {nm!r}")
        if cols[nm] <= 0:
            raise ValueError(f"refine axis {nm!r} must be positive")

    x0 = np.asarray([cols[nm] for nm in names], np.float64)
    if bounds is None:
        bounds = {nm: (x0[i] / span, x0[i] * span)
                  for i, nm in enumerate(names)}
    lo = jnp.log(_as_f64([bounds[nm][0] for nm in names]))
    hi = jnp.log(_as_f64([bounds[nm][1] for nm in names]))

    kern = TOPOLOGY_ARRAYS[topology]
    bits, xfers = traffic.total_bits, traffic.n_transfers

    def metrics_of(theta):
        c = {k: _as_f64(v) for k, v in cols.items()}
        x = jnp.exp(theta)
        for i, nm in enumerate(names):
            c[nm] = x[i]
        fields = kern(c, xp=jnp)
        dev = {k: c[k] for k in EVAL_DEVICE_FIELDS}
        return eval_network_math(fields, dev, _as_f64(bits), _as_f64(xfers),
                                 _as_f64(active_fraction))

    def loss_of(theta):
        m = metrics_of(theta)
        if objective == "edp":
            return jnp.log(m["energy_j"]) + jnp.log(m["latency_s"])
        return jnp.log(m[objective])

    value_and_grad = jax.jit(jax.value_and_grad(loss_of))
    metrics_jit = jax.jit(metrics_of)

    theta0 = jnp.clip(jnp.log(_as_f64(x0)), lo, hi)
    best_loss, best_theta, trace, _ = _projected_descent(
        value_and_grad, theta0, lo, hi, steps, lr)

    # projection happens in (possibly float32) log-space; snap the reported
    # values back inside the exact float64 box, then re-evaluate the
    # metrics AT the clipped point — the reported metrics must describe the
    # reported design (they used to be evaluated at the pre-clip iterate,
    # so they diverged whenever the box projection was active)
    lo_box = np.asarray([bounds[nm][0] for nm in names], np.float64)
    hi_box = np.asarray([bounds[nm][1] for nm in names], np.float64)
    x_best = np.clip(np.exp(np.asarray(best_theta, np.float64)),
                     lo_box, hi_box)
    metrics = {k: float(v)
               for k, v in metrics_jit(jnp.log(_as_f64(x_best))).items()}
    if objective == "edp":
        best_loss = float(np.log(metrics["energy_j"])
                          + np.log(metrics["latency_s"]))
    else:
        best_loss = float(np.log(metrics[objective]))
    if best_loss > trace[0]:
        # clipping moved the iterate enough to undo the descent gain: fall
        # back to the seed point, keeping refined_value <= start_value
        x_best = np.clip(np.exp(np.asarray(theta0, np.float64)),
                         lo_box, hi_box)
        metrics = {k: float(v) for k, v in metrics_jit(theta0).items()}
        best_loss = trace[0]
    return {
        "topology": topology,
        "objective": objective,
        "refine_axes": list(names),
        "start": {nm: float(x0[i]) for i, nm in enumerate(names)},
        "refined": {nm: float(x_best[i]) for i, nm in enumerate(names)},
        "start_value": float(np.exp(trace[0])),
        "refined_value": float(np.exp(best_loss)),
        "improvement": float(1.0 - np.exp(best_loss - trace[0])),
        "loss_trace": trace,
        "metrics": metrics,
    }


def refine_front_point(
    spec: GridSpec,
    traffic: Traffic,
    index: int,
    **kwargs,
) -> Dict[str, object]:
    """`refine_continuous` seeded from flat grid row `index` of `spec` —
    the "descend locally from a Pareto point" entry point."""
    cfg = spec.config_at(int(index))
    topology = cfg.pop("topology")
    return refine_continuous(topology, cfg, traffic, **kwargs)


# --------------------------------------------------------------------------
# Co-design gradient refinement: accelerator + network axes jointly
# --------------------------------------------------------------------------


# the relaxable accelerator-side axes: per-chiplet unit/vector counts plus
# the two compute-rate/energy scalars of `core.accelerator._accel_mix_math`
ACCEL_REFINE_AXES: Tuple[str, ...] = (
    "n_units", "vector_size", "mac_rate_hz", "lambda_slot_energy_j")


def _objective_value(metrics: Mapping[str, object], objective: str):
    """Scalarize a metric dict: "edp" = energy * latency, anything else is
    the metric itself.  Works on floats and on (M, N) metric grids."""
    if objective == "edp":
        return (np.asarray(metrics["energy_j"], np.float64)
                * np.asarray(metrics["latency_s"], np.float64))
    return np.asarray(metrics[objective], np.float64)


def _int_neighbors(v: float, extra: Optional[float] = None,
                   lo: int = 1) -> List[int]:
    """Admissible integer neighbors of a relaxed value: floor and ceil
    (clamped at `lo`), plus the seed's original value when given — the
    fallback that keeps the round-and-rescore candidate set from ever
    excluding the known-feasible seed setting."""
    opts = {int(np.floor(v)), int(np.ceil(v))}
    if extra is not None:
        opts.add(int(round(extra)))
    return sorted(o for o in opts if o >= lo) or [lo]


def _as_workload_batch(wl, weights) -> Tuple[List[Workload], np.ndarray]:
    """Normalize the `wl` argument of the refiners: one `Workload` or a
    sequence of them, with optional positive per-workload weights
    (normalized to sum 1; uniform when omitted)."""
    wls = [wl] if isinstance(wl, Workload) else list(wl)
    if not wls:
        raise ValueError("need at least one workload to refine against")
    for w in wls:
        if not isinstance(w, Workload):
            raise TypeError(
                f"expected Workload entries, got {type(w).__name__}")
    if weights is None:
        wts = np.full(len(wls), 1.0 / len(wls), np.float64)
    else:
        wts = np.asarray(list(weights), np.float64)
        if wts.shape != (len(wls),):
            raise ValueError(
                f"weights shape {wts.shape} does not match "
                f"{len(wls)} workloads")
        if not np.all(wts > 0):
            raise ValueError("workload weights must all be positive")
        wts = wts / wts.sum()
    return wls, wts


def _combined_value(values: Sequence[float], weights: np.ndarray) -> float:
    """The multi-workload scalarization: weighted geometric mean of the
    per-workload objective values.  A single workload short-circuits to its
    exact objective value (no exp/log round-trip), so one-workload
    refinement reports bit-identically to the single-workload engine."""
    vals = np.asarray(values, np.float64)
    if vals.shape[0] == 1:
        return float(vals[0])
    return float(np.exp(np.sum(np.asarray(weights, np.float64)
                               * np.log(vals))))


def refine_codesign(
    spec: GridSpec,
    mixes: Sequence,
    wl,
    flat_index: int,
    *,
    refine_axes: Sequence[str] = DEFAULT_REFINE_AXES,
    accel_axes: Sequence[str] = ACCEL_REFINE_AXES,
    objective: str = "edp",
    method: str = "first_order",
    weights: Optional[Sequence[float]] = None,
    steps: int = 32,
    lr: float = 0.1,
    span: float = 4.0,
    bounds: Optional[Mapping[str, Tuple[float, float]]] = None,
    mac_rate_hz: float = 5e9,
    lambda_slot_energy_j: float = 30e-15,
    adaptive_gateways: bool = True,
    transfers_per_layer: int = 16,
    max_candidates: int = 1024,
    tr_radius: float = 0.5,
    max_sweeps: int = 4,
) -> Dict[str, object]:
    """Jointly refine one `codesign_pareto` frontier point over accelerator
    AND network axes, then snap back to a feasible integer design.

    Seeds from flat index `flat_index` (decoded via `codesign_config_at`),
    relaxes the accelerator axes continuously (the grid kernel's
    ``relaxed=True`` mode replaces ceil(L/V) with max(L/V, 1) so per-chiplet
    `n_units`/`vector_size`, `mac_rate_hz` and `lambda_slot_energy_j` all
    carry nonzero gradients; zero-unit padding chiplets stay exactly
    masked), and descends the concatenated accelerator + `refine_axes`
    network parameter vector in log-space.

    `method` picks the descent + integerization strategy:

    - "first_order": the fixed-lr projected-gradient loop shared with
      `refine_continuous`, followed by the one-shot floor/ceil
      round-and-rescore over the integer-neighbor cross product.
    - "trust_region": second-order log-space trust-region descent
      (`_trust_region_descent` — quadratic model from `jax.hessian` of the
      relaxed objective, adaptive radius, accept/reject on exactly
      re-evaluated steps, traced in forced float64 via `engine_x64`),
      followed by the floor/ceil snap AND a coordinate-wise integer line
      search (`_coordinate_int_search`) seeded at the snap winner: each
      discrete axis walks in +-1 integer steps, every candidate exactly
      re-scored through `evaluate_accelerator_grid`, to a local integer
      optimum.  The line-search result weakly dominates the plain snap by
      construction (it starts there).

    `wl` is one `Workload` or a sequence of them; with several, the scalar
    objective is the `weights`-weighted geometric mean of the per-workload
    objective values (weights normalized to sum 1, uniform by default) and
    the returned metrics carry a "per_workload" breakdown for the final
    integer design.

    Round-and-rescore: every discrete axis (per-chiplet vector_size /
    n_units, and any refined network axis in `core.sweep.INTEGER_AXES`) is
    snapped to its floor/ceil integer neighbors (seed value kept as a
    fallback for the network axes), every candidate combination is re-scored
    EXACTLY through `evaluate_accelerator_grid` (relaxed=False), and the
    best candidate wins — re-scored once more as a single (M=1, N=1) cell
    so the reported metrics are bit-identical to any later standalone
    evaluation of that design.  If no candidate beats the seed's exact
    score, the seed is returned (improvement 0.0): the refined point is
    always a feasible integer design and never worse than its seed.
    Candidates whose network settings the topology rejects (e.g. SPACX
    with < 8 gateways) are filtered out before scoring; the integer line
    search scores rejected candidates as +inf.

    Returns a dict with "seed"/"refined" {config, metrics, per_workload,
    value} (configs are `core.fabric.Fabric.from_config`-consumable;
    "metrics" is the first workload's exact metric dict, "per_workload" the
    full per-workload list, "value" the scalarized objective),
    "improvement" (fractional objective gain, >= 0), per-axis
    gradient-magnitude "sensitivity" at the seed, the descent "loss_trace",
    the "relaxed" (pre-snap) axis values, "n_candidates" scored, plus
    "method", "workloads"/"weights", and — for the trust-region method —
    "tr_stats" (accept/reject counts, radius trajectory) and "line_search"
    ({snap_value, value, n_scored, n_sweeps}).
    """
    from repro.core.accelerator import (
        ACCEL_REPORT_FIELDS, ChipletSpec, _accel_mix_math,
        evaluate_accelerator_grid, layer_columns)

    _check_objective(objective, ACCEL_REPORT_FIELDS, "refine_codesign")
    if method not in ("first_order", "trust_region"):
        raise ValueError(
            f"unknown refine method {method!r}; valid methods are "
            "'first_order' or 'trust_region'")
    bad = [a for a in accel_axes if a not in ACCEL_REFINE_AXES]
    if bad:
        raise KeyError(
            f"unknown accelerator refine axes {bad!r}; valid axes are "
            f"{list(ACCEL_REFINE_AXES)}")
    wls, wts = _as_workload_batch(wl, weights)

    cfg = codesign_config_at(spec, mixes, flat_index)
    seed_mix = [ChipletSpec(int(c.n_units), int(c.vector_size))
                for c in cfg.pop("chiplets")]
    mix_id = cfg.pop("mix")
    topology = cfg.pop("topology")
    kern = TOPOLOGY_ARRAYS[topology]

    cols: Dict[str, float] = dict(spec.base)
    for k, v in cfg.items():
        cols[k] = float(v)
    net_names = tuple(refine_axes)
    for nm in net_names:
        if nm not in cols:
            raise KeyError(f"unknown refine axis {nm!r}")
        if cols[nm] <= 0:
            raise ValueError(f"refine axis {nm!r} must be positive")

    # ---- parameter vector: network axes ++ relaxed accelerator axes ----
    C = len(seed_mix)
    active = [j for j in range(C) if seed_mix[j].n_units > 0]
    entries: List[Tuple[str, object, float]] = [
        ("net", nm, cols[nm]) for nm in net_names]
    if "n_units" in accel_axes:
        entries += [("units", j, float(seed_mix[j].n_units))
                    for j in active]
    if "vector_size" in accel_axes:
        entries += [("vec", j, float(seed_mix[j].vector_size))
                    for j in active]
    if "mac_rate_hz" in accel_axes:
        entries.append(("mac", None, float(mac_rate_hz)))
    if "lambda_slot_energy_j" in accel_axes:
        entries.append(("slot", None, float(lambda_slot_energy_j)))
    if not entries:
        raise ValueError(
            "nothing to refine: refine_axes and accel_axes are both empty")

    def _label(kind, key):
        if kind == "net":
            return key
        if kind == "units":
            return f"n_units[{key}]"
        if kind == "vec":
            return f"vector_size[{key}]"
        return "mac_rate_hz" if kind == "mac" else "lambda_slot_energy_j"

    labels = [_label(k, j) for k, j, _ in entries]
    x0 = np.asarray([v for _, _, v in entries], np.float64)
    lo_f, hi_f = x0 / span, x0 * span
    for i, (kind, _, _) in enumerate(entries):
        if kind in ("units", "vec"):  # count axes never relax below 1
            lo_f[i] = max(lo_f[i], 1.0)
            hi_f[i] = max(hi_f[i], 1.0)
    if bounds:
        for i, lb in enumerate(labels):
            if lb in bounds:
                lo_f[i], hi_f[i] = bounds[lb]
    lo, hi = jnp.log(_as_f64(lo_f)), jnp.log(_as_f64(hi_f))

    # ---- relaxed differentiable loss: topology kernel + accel kernel ----
    # layer columns stay host-side float64 and convert inside the traced
    # function, so the trust-region path (traced under engine_x64) sees
    # float64 constants while the first-order path keeps session precision
    lcs_np = [{k: np.asarray(v, np.float64)
               for k, v in layer_columns(w).items()} for w in wls]
    units0_np = np.asarray([float(c.n_units) for c in seed_mix], np.float64)
    vec0_np = np.asarray([float(c.vector_size) for c in seed_mix],
                         np.float64)

    def relaxed_metrics(theta, lc_np):
        x = jnp.exp(theta)
        c = {k: _as_f64(v) for k, v in cols.items()}
        lc = {k: _as_f64(v) for k, v in lc_np.items()}
        units, vec = _as_f64(units0_np), _as_f64(vec0_np)
        mac, slot = _as_f64(mac_rate_hz), _as_f64(lambda_slot_energy_j)
        xfers = _as_f64(float(transfers_per_layer))
        for i, (kind, key, _) in enumerate(entries):
            if kind == "net":
                c[key] = x[i]
            elif kind == "units":
                units = units.at[key].set(x[i])
            elif kind == "vec":
                vec = vec.at[key].set(x[i])
            elif kind == "mac":
                mac = x[i]
            else:
                slot = x[i]
        fields = kern(c, xp=jnp)
        nets1 = {k: jnp.reshape(fields[k], (1,)) for k in MODEL_FIELDS}
        dev1 = {k: jnp.reshape(c[k], (1,)) for k in EVAL_DEVICE_FIELDS}
        mem_bw1 = jnp.reshape(
            c["n_mem_chiplets"] * c["mem_bw_bytes_per_s"], (1,))
        m = _accel_mix_math(
            {"n_units": units, "vector_size": vec}, None, lc, nets1, dev1,
            mem_bw1, mac, slot, xfers, adaptive=adaptive_gateways,
            relaxed=True)
        return {k: v[0] for k, v in m.items()}

    def loss_of(theta):
        # weighted sum of per-workload log objectives = log of the
        # weighted-geomean scalarization (one workload: plain log loss)
        total = 0.0
        for wt, lc_np in zip(wts, lcs_np):
            m = relaxed_metrics(theta, lc_np)
            if objective == "edp":
                term = jnp.log(m["energy_j"]) + jnp.log(m["latency_s"])
            else:
                term = jnp.log(m[objective])
            total = total + float(wt) * term
        return total

    value_and_grad = jax.jit(jax.value_and_grad(loss_of))
    tr_stats: Optional[Dict[str, object]] = None
    if method == "first_order":
        theta0 = jnp.clip(jnp.log(_as_f64(x0)), lo, hi)
        _, best_theta, trace, grad0 = _projected_descent(
            value_and_grad, theta0, lo, hi, steps, lr)
    else:
        # second-order path: force float64 tracing/execution (the Hessian
        # of the relaxed objective is too ill-conditioned for f32) and keep
        # the box in exact f64 logs host-side
        hess_fn = jax.jit(jax.hessian(loss_of))
        lo64, hi64 = np.log(lo_f), np.log(hi_f)
        theta0_np = np.clip(np.log(x0), lo64, hi64)
        with engine_x64():
            def _vg(t):
                v, g = value_and_grad(_as_f64(t))
                return float(v), np.asarray(g, np.float64)

            _, best_theta, trace, grad0, tr_stats = _trust_region_descent(
                _vg, lambda t: hess_fn(_as_f64(t)), theta0_np, lo64, hi64,
                steps, radius=tr_radius)
    sensitivity = {lb: float(abs(g)) for lb, g in zip(labels, grad0)}
    x_best = np.clip(np.exp(np.asarray(best_theta, np.float64)), lo_f, hi_f)

    # ---- round-and-rescore: snap discrete axes, score exactly, keep best --
    refined_net = {nm: float(cols[nm]) for nm in net_names}
    refined_units = np.asarray([float(c.n_units) for c in seed_mix])
    refined_vec = np.asarray([float(c.vector_size) for c in seed_mix])
    refined_mac = float(mac_rate_hz)
    refined_slot = float(lambda_slot_energy_j)
    for i, (kind, key, _) in enumerate(entries):
        v = float(x_best[i])
        if kind == "net":
            refined_net[key] = v
        elif kind == "units":
            refined_units[key] = v
        elif kind == "vec":
            refined_vec[key] = v
        elif kind == "mac":
            refined_mac = v
        else:
            refined_slot = v

    unit_opts = [[seed_mix[j].n_units] for j in range(C)]
    vec_opts = [[seed_mix[j].vector_size] for j in range(C)]
    if "n_units" in accel_axes:
        for j in active:
            unit_opts[j] = _int_neighbors(refined_units[j])
    if "vector_size" in accel_axes:
        for j in active:
            vec_opts[j] = _int_neighbors(refined_vec[j])
    net_int = [nm for nm in net_names if nm in INTEGER_AXES]
    net_opts = {nm: _int_neighbors(refined_net[nm], extra=cols[nm])
                for nm in net_int}

    n_mix_full = int(np.prod([len(u) * len(v)
                              for u, v in zip(unit_opts, vec_opts)]))
    n_net_full = int(np.prod([len(v) for v in net_opts.values()])
                     ) if net_opts else 1
    if n_mix_full * n_net_full <= max_candidates:
        per_chip = [[(u, v) for u in uo for v in vo]
                    for uo, vo in zip(unit_opts, vec_opts)]
        mix_cands = [tuple(chips) for chips in itertools.product(*per_chip)]
        net_cands = [dict(zip(net_opts, vals))
                     for vals in itertools.product(*net_opts.values())]
    else:
        # corner count exploded past max_candidates: score the nearest-
        # rounded design plus every single-axis flip instead of the full
        # cross product
        near_u = [min(uo, key=lambda o: abs(o - refined_units[j]))
                  for j, uo in enumerate(unit_opts)]
        near_v = [min(vo, key=lambda o: abs(o - refined_vec[j]))
                  for j, vo in enumerate(vec_opts)]
        base = tuple(zip(near_u, near_v))
        mix_cands = [base]
        for j in range(C):
            for u in unit_opts[j]:
                if u != near_u[j]:
                    alt = list(base)
                    alt[j] = (u, near_v[j])
                    mix_cands.append(tuple(alt))
            for v in vec_opts[j]:
                if v != near_v[j]:
                    alt = list(base)
                    alt[j] = (near_u[j], v)
                    mix_cands.append(tuple(alt))
        near_net = {nm: min(net_opts[nm],
                            key=lambda o: abs(o - refined_net[nm]))
                    for nm in net_opts}
        net_cands = [dict(near_net)]
        for nm in net_opts:
            for o in net_opts[nm]:
                if o != near_net[nm]:
                    alt = dict(near_net)
                    alt[nm] = o
                    net_cands.append(alt)
    seed_net = {nm: int(round(cols[nm])) for nm in net_int}
    if seed_net not in net_cands:
        net_cands.append(seed_net)

    # drop candidates the topology itself rejects (e.g. SPACX < 8 gateways)
    valid_net = []
    for cand in net_cands:
        c1 = {k: np.full(1, v, np.float64) for k, v in cols.items()}
        for nm in net_names:
            c1[nm][:] = refined_net[nm]
        for nm, v in cand.items():
            c1[nm][:] = float(v)
        try:
            kern(c1)
        except (ValueError, FloatingPointError):
            continue
        valid_net.append(cand)
    if not valid_net:
        # even the seed integers fail under the refined continuous values:
        # retreat to the seed network configuration wholesale
        valid_net = [seed_net]
        for nm in net_names:
            if nm not in net_int:
                refined_net[nm] = float(cols[nm])

    n_net = len(valid_net)
    cand_cols = {k: np.full(n_net, v, np.float64) for k, v in cols.items()}
    for nm in net_names:
        cand_cols[nm][:] = refined_net[nm]
    for i, cand in enumerate(valid_net):
        for nm, v in cand.items():
            cand_cols[nm][i] = float(v)
    nets = _network_columns_arrays(
        cand_cols, np.zeros(n_net, np.int64), (topology,))
    mem_bw = cand_cols["n_mem_chiplets"] * cand_cols["mem_bw_bytes_per_s"]
    cand_mixes = [[ChipletSpec(int(u), int(v)) for (u, v) in chips]
                  for chips in mix_cands]
    def _score_grid(ms, nets_, cols_, mbw_):
        """Scalarized (M, N) candidate scores: the weights-weighted sum of
        per-workload log objectives — i.e. the log of the weighted-geomean
        objective, so argmin matches the scalarization exactly."""
        total = None
        for wt, w in zip(wts, wls):
            o = evaluate_accelerator_grid(
                w, ms, nets_, cols_, mbw_, mac_rate_hz=refined_mac,
                lambda_slot_energy_j=refined_slot,
                adaptive_gateways=adaptive_gateways,
                transfers_per_layer=transfers_per_layer)
            s = float(wt) * np.log(_objective_value(o, objective))
            total = s if total is None else total + s
        return total

    score = _score_grid(cand_mixes, nets, cand_cols, mem_bw)
    mi, ni = np.unravel_index(int(np.argmin(score)), score.shape)

    def _score_single(mix, net_vals: Mapping[str, float], mac, slot):
        """Exact (M=1, N=1) per-workload scores — bit-identical to any later
        standalone `evaluate_accelerator_grid` call on the same design.
        Returns (per_workload_metric_dicts, scalarized_value)."""
        c1 = {k: np.full(1, v, np.float64) for k, v in cols.items()}
        for nm, v in net_vals.items():
            c1[nm][:] = float(v)
        n1 = _network_columns_arrays(c1, np.zeros(1, np.int64), (topology,))
        mbw = c1["n_mem_chiplets"] * c1["mem_bw_bytes_per_s"]
        per = []
        for w in wls:
            o = evaluate_accelerator_grid(
                w, [mix], n1, c1, mbw, mac_rate_hz=mac,
                lambda_slot_energy_j=slot,
                adaptive_gateways=adaptive_gateways,
                transfers_per_layer=transfers_per_layer)
            per.append({k: float(v[0, 0]) for k, v in o.items()})
        value = _combined_value(
            [float(_objective_value(m, objective)) for m in per], wts)
        return per, value

    win_net = dict(refined_net)
    win_net.update({nm: float(v) for nm, v in valid_net[ni].items()})
    win_mix = list(cand_mixes[mi])

    line_search: Optional[Dict[str, object]] = None
    if method == "trust_region":
        # coordinate-wise integer line search seeded at the floor/ceil snap
        # winner: walk every discrete axis in +-1 steps (others held), each
        # candidate exactly re-scored, to a local integer optimum — the
        # result can only improve on the snap (it starts there)
        ls_vars: Dict[Tuple[str, object], int] = {}
        ls_lo: Dict[Tuple[str, object], int] = {}
        ls_hi: Dict[Tuple[str, object], int] = {}
        for i, (kind, key, _) in enumerate(entries):
            if kind == "units":
                v = int(win_mix[key].n_units)
            elif kind == "vec":
                v = int(win_mix[key].vector_size)
            elif kind == "net" and key in net_int:
                v = int(round(win_net[key]))
            else:
                continue
            ls_vars[(kind, key)] = v
            ls_lo[(kind, key)] = min(int(np.ceil(lo_f[i] - 1e-9)), v)
            ls_hi[(kind, key)] = max(int(np.floor(hi_f[i] + 1e-9)), v)

        def _ls_score(vals: Mapping) -> float:
            mix = [ChipletSpec(
                int(vals.get(("units", j), win_mix[j].n_units)),
                int(vals.get(("vec", j), win_mix[j].vector_size)))
                for j in range(C)]
            if not any(csp.n_units > 0 for csp in mix):
                return float(np.inf)
            nv = dict(win_net)
            for nm in net_int:
                if ("net", nm) in vals:
                    nv[nm] = float(vals[("net", nm)])
            c1 = {k: np.full(1, v, np.float64) for k, v in cols.items()}
            for nm, v in nv.items():
                c1[nm][:] = float(v)
            try:
                n1 = _network_columns_arrays(
                    c1, np.zeros(1, np.int64), (topology,))
            except (ValueError, FloatingPointError):
                return float(np.inf)  # topology rejects this integer point
            mbw = c1["n_mem_chiplets"] * c1["mem_bw_bytes_per_s"]
            return float(_score_grid([mix], n1, c1, mbw)[0, 0])

        if ls_vars:
            snap_score = _ls_score(ls_vars)
            best_vals, best_score, ls_stats = _coordinate_int_search(
                ls_vars, ls_lo, ls_hi, _ls_score, max_sweeps=max_sweeps)
            if best_score < snap_score:
                win_mix = [ChipletSpec(
                    int(best_vals.get(("units", j), win_mix[j].n_units)),
                    int(best_vals.get(("vec", j), win_mix[j].vector_size)))
                    for j in range(C)]
                for nm in net_int:
                    if ("net", nm) in best_vals:
                        win_net[nm] = float(best_vals[("net", nm)])
            line_search = {
                "snap_value": float(np.exp(snap_score)),
                "value": float(np.exp(min(best_score, snap_score))),
                "n_scored": int(ls_stats["n_scored"]),
                "n_sweeps": int(ls_stats["n_sweeps"]),
            }
        else:
            line_search = {"snap_value": float(np.exp(score[mi, ni])),
                           "value": float(np.exp(score[mi, ni])),
                           "n_scored": 0, "n_sweeps": 0}

    win_per, win_value = _score_single(
        win_mix, win_net, refined_mac, refined_slot)
    win_metrics = win_per[0]
    seed_per, seed_value = _score_single(
        seed_mix, {}, float(mac_rate_hz), float(lambda_slot_energy_j))
    seed_metrics = seed_per[0]

    seed_cfg: Dict[str, object] = {"topology": topology, **cfg}
    seed_cfg.update({
        "mix": mix_id, "chiplets": list(seed_mix),
        "mac_rate_hz": float(mac_rate_hz),
        "lambda_slot_energy_j": float(lambda_slot_energy_j)})
    if win_value < seed_value:
        ref_cfg: Dict[str, object] = {"topology": topology, **cfg}
        for nm in net_names:
            ref_cfg[nm] = float(win_net[nm])
        ref_cfg.update({
            "mix": mix_id, "chiplets": list(win_mix),
            "mac_rate_hz": refined_mac,
            "lambda_slot_energy_j": refined_slot})
        refined = {"config": ref_cfg, "metrics": win_metrics,
                   "per_workload": win_per, "value": win_value,
                   "chiplets": list(win_mix)}
    else:
        # no snapped candidate beat the exact seed score: keep the seed, so
        # the refined point is never worse than where it started
        refined = {"config": dict(seed_cfg), "metrics": dict(seed_metrics),
                   "per_workload": [dict(m) for m in seed_per],
                   "value": seed_value, "chiplets": list(seed_mix)}

    return {
        "flat_index": int(flat_index),
        "topology": topology,
        "objective": objective,
        "method": method,
        "workloads": [w.name for w in wls],
        "weights": [float(x) for x in wts],
        "labels": labels,
        "seed": {"config": seed_cfg, "metrics": seed_metrics,
                 "per_workload": seed_per, "value": seed_value},
        "refined": refined,
        "improvement": float(1.0 - refined["value"] / seed_value),
        "sensitivity": sensitivity,
        "loss_trace": trace,
        "relaxed": {lb: float(x_best[i]) for i, lb in enumerate(labels)},
        "n_candidates": len(cand_mixes) * n_net,
        "tr_stats": tr_stats,
        "line_search": line_search,
    }


def refine_trust_region(spec: GridSpec, mixes: Sequence, wl, flat_index: int,
                        **kwargs) -> Dict[str, object]:
    """`refine_codesign(method="trust_region")`: second-order log-space
    trust-region descent on the relaxed objective followed by a
    coordinate-wise integer line search on the discrete axes, optionally
    jointly over a weighted batch of workloads.  See `refine_codesign` for
    the full contract."""
    kwargs.setdefault("method", "trust_region")
    return refine_codesign(spec, mixes, wl, flat_index, **kwargs)


def _front_objective(front: ParetoFront, objective: str) -> np.ndarray:
    """Scalar objective of each front row from its stored columns ("edp" =
    energy * latency); falls back to the first objective column when the
    requested metric isn't one the front tracks."""
    names = list(front.objectives)
    if objective == "edp" and {"energy_j", "latency_s"} <= set(names):
        return (front.points[:, names.index("energy_j")]
                * front.points[:, names.index("latency_s")])
    if objective in names:
        return front.points[:, names.index(objective)]
    return front.points[:, 0]


def refine_front(
    front: ParetoFront,
    spec: GridSpec,
    mixes: Sequence,
    wl,
    *,
    top_k: Optional[int] = None,
    objective: str = "edp",
    method: str = "first_order",
    **kwargs,
) -> Dict[str, object]:
    """Refine every (or the `top_k` best-objective) row of a
    `codesign_pareto` front through `refine_codesign`, then merge the
    refined integer designs back into the seed front with `merge_fronts`.

    `method` selects the descent engine per row ("first_order" or
    "trust_region" — see `refine_codesign`); `wl` may be a single
    `Workload` or a weighted batch (pass `weights=` through kwargs), in
    which case each row is refined against the scalarized multi-workload
    objective and the merged front's points are the FIRST workload's exact
    metrics for the final integer designs.

    Merging unions the point sets, so the merged front weakly dominates the
    seed front by construction — asserted before returning (a violation
    would mean the exact rescore and the front machinery disagree, i.e. a
    real bug).  Per-axis gradient-magnitude sensitivities are averaged
    across the refined seeds: which axis the objective is most elastic to
    along this frontier.

    Returns {"front", "seed_front", "results", "configs", "n_improved",
    "sensitivity"}.  `configs` decodes every merged-front row — refined
    rows to their snapped refined config, surviving seed rows via
    `codesign_config_at` — each directly consumable by
    `core.fabric.Fabric.from_config`.
    """
    if front.size == 0:
        raise ValueError("empty front: nothing to refine")
    order = np.argsort(_front_objective(front, objective), kind="stable")
    chosen = order if top_k is None else order[:max(1, int(top_k))]
    results = [refine_codesign(spec, mixes, wl, int(front.indices[i]),
                               objective=objective, method=method, **kwargs)
               for i in chosen]
    obj_names = front.objectives
    ref_pts = np.asarray(
        [[r["refined"]["metrics"][k] for k in obj_names] for r in results],
        np.float64)
    ref_idx = np.asarray([r["flat_index"] for r in results], np.int64)
    merged = merge_fronts(front, ParetoFront(obj_names, ref_pts, ref_idx))

    # weak-dominance gate: every seed point must be dominated by, or still
    # present in, the merged front
    dom = _dominated_by(front.points, merged.points)
    present = np.asarray([
        bool(np.all(merged.points == p, axis=1).any())
        for p in front.points])
    if not bool(np.all(dom | present)):
        raise AssertionError(
            "refined front fails to weakly dominate its seed front")

    ref_map = {(int(r["flat_index"]), tuple(pt)): r["refined"]["config"]
               for r, pt in zip(results, ref_pts)}
    configs: List[Dict[str, object]] = []
    for i in range(merged.size):
        key = (int(merged.indices[i]), tuple(merged.points[i]))
        hit = ref_map.get(key)
        configs.append(hit if hit is not None else
                       codesign_config_at(spec, mixes,
                                          int(merged.indices[i])))
    sens: Dict[str, List[float]] = {}
    for r in results:
        for lb, v in r["sensitivity"].items():
            sens.setdefault(lb, []).append(v)
    return {
        "front": merged,
        "seed_front": front,
        "results": results,
        "configs": configs,
        "n_improved": int(sum(r["improvement"] > 0 for r in results)),
        "sensitivity": {lb: float(np.mean(v)) for lb, v in sens.items()},
    }

"""Workload models: the six CNNs of the paper's evaluation (Fig. 4 / Fig. 6)
plus a generic GEMM workload hook for the assigned LM architectures.

Each workload is a list of layers with MAC counts, operand byte counts, and
dot-product lengths (the quantity that determines photonic MAC-unit vector
utilization in 2.5D-CrossLight's heterogeneous chiplets).

Interposer traffic model (Sec. V): every layer reads weights + input
activations from the memory chiplet GLB (SWMR broadcast to compute chiplets)
and writes output activations back (SWSR).  8-bit operands, matching the
CrossLight line of work (noncoherent photonic accelerators quantize to <=8b).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.core.power import Traffic


@dataclasses.dataclass(frozen=True)
class Layer:
    name: str
    macs: float
    weight_bytes: float
    in_bytes: float
    out_bytes: float
    dot_length: int      # length of each dot product (R*S*C or fan-in)
    n_dots: float        # number of dot products (K * Hout * Wout or fan-out)


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    layers: List[Layer]

    @property
    def total_macs(self) -> float:
        return sum(l.macs for l in self.layers)

    def traffic(self, transfers_per_layer: int = 16) -> Traffic:
        return Traffic(
            bytes_read=sum(l.weight_bytes + l.in_bytes for l in self.layers),
            bytes_written=sum(l.out_bytes for l in self.layers),
            n_transfers=transfers_per_layer * len(self.layers),
        )


DTYPE_BYTES = 1  # 8-bit operands


def _conv(name, cin, cout, k, stride, hin, groups=1) -> tuple[Layer, int]:
    hout = max(1, hin // stride)
    macs = (cout * cin // groups) * k * k * hout * hout
    w = (cout * cin // groups) * k * k * DTYPE_BYTES
    i = cin * hin * hin * DTYPE_BYTES
    o = cout * hout * hout * DTYPE_BYTES
    dot = (cin // groups) * k * k
    return Layer(name, macs, w, i, o, dot, cout * hout * hout), hout


def _fc(name, fin, fout) -> Layer:
    return Layer(name, fin * fout, fin * fout * DTYPE_BYTES,
                 fin * DTYPE_BYTES, fout * DTYPE_BYTES, fin, fout)


def lenet5() -> Workload:
    ls: List[Layer] = []
    l, h = _conv("c1", 1, 6, 5, 1, 32); ls.append(l); h //= 2
    l, h = _conv("c2", 6, 16, 5, 1, h); ls.append(l); h //= 2
    ls += [_fc("f1", 16 * 5 * 5, 120), _fc("f2", 120, 84), _fc("f3", 84, 10)]
    return Workload("LeNet5", ls)


def vgg16() -> Workload:
    ls: List[Layer] = []
    h, cin = 224, 3
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    for i, c in enumerate(cfg):
        if c == "M":
            h //= 2
            continue
        l, h = _conv(f"c{i}", cin, c, 3, 1, h)
        ls.append(l)
        cin = c
    ls += [_fc("f1", 512 * 7 * 7, 4096), _fc("f2", 4096, 4096), _fc("f3", 4096, 1000)]
    return Workload("VGG16", ls)


def resnet18() -> Workload:
    ls: List[Layer] = []
    l, h = _conv("stem", 3, 64, 7, 2, 224); ls.append(l); h //= 2  # maxpool
    cin = 64
    for si, (c, s) in enumerate([(64, 1), (128, 2), (256, 2), (512, 2)]):
        for b in range(2):
            st = s if b == 0 else 1
            l, h2 = _conv(f"s{si}b{b}a", cin, c, 3, st, h); ls.append(l)
            l, _ = _conv(f"s{si}b{b}b", c, c, 3, 1, h2); ls.append(l)
            if st != 1 or cin != c:
                l, _ = _conv(f"s{si}b{b}d", cin, c, 1, st, h); ls.append(l)
            h, cin = h2, c
    ls.append(_fc("fc", 512, 1000))
    return Workload("ResNet18", ls)


def mobilenet_v2() -> Workload:
    ls: List[Layer] = []
    l, h = _conv("stem", 3, 32, 3, 2, 224); ls.append(l)
    cin = 32
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for bi, (t, c, n, s) in enumerate(cfg):
        for i in range(n):
            st = s if i == 0 else 1
            mid = cin * t
            if t != 1:
                l, _ = _conv(f"b{bi}.{i}.e", cin, mid, 1, 1, h); ls.append(l)
            l, h2 = _conv(f"b{bi}.{i}.d", mid, mid, 3, st, h, groups=mid); ls.append(l)
            l, _ = _conv(f"b{bi}.{i}.p", mid, c, 1, 1, h2); ls.append(l)
            h, cin = h2, c
    l, _ = _conv("head", cin, 1280, 1, 1, h); ls.append(l)
    ls.append(_fc("fc", 1280, 1000))
    return Workload("MobileNetV2", ls)


def efficientnet_b0() -> Workload:
    ls: List[Layer] = []
    l, h = _conv("stem", 3, 32, 3, 2, 224); ls.append(l)
    cin = 32
    cfg = [(1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5), (6, 80, 3, 2, 3),
           (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3)]
    for bi, (t, c, n, s, k) in enumerate(cfg):
        for i in range(n):
            st = s if i == 0 else 1
            mid = cin * t
            if t != 1:
                l, _ = _conv(f"b{bi}.{i}.e", cin, mid, 1, 1, h); ls.append(l)
            l, h2 = _conv(f"b{bi}.{i}.d", mid, mid, k, st, h, groups=mid); ls.append(l)
            l, _ = _conv(f"b{bi}.{i}.p", mid, c, 1, 1, h2); ls.append(l)
            h, cin = h2, c
    l, _ = _conv("head", cin, 1280, 1, 1, h); ls.append(l)
    ls.append(_fc("fc", 1280, 1000))
    return Workload("EfficientNetB0", ls)


def densenet121() -> Workload:
    ls: List[Layer] = []
    growth = 32
    l, h = _conv("stem", 3, 64, 7, 2, 224); ls.append(l); h //= 2
    cin = 64
    for bi, n in enumerate([6, 12, 24, 16]):
        for i in range(n):
            l, _ = _conv(f"d{bi}.{i}.1", cin, 4 * growth, 1, 1, h); ls.append(l)
            l, _ = _conv(f"d{bi}.{i}.3", 4 * growth, growth, 3, 1, h); ls.append(l)
            cin += growth
        if bi < 3:
            l, _ = _conv(f"t{bi}", cin, cin // 2, 1, 1, h); ls.append(l)
            cin //= 2
            h //= 2
    ls.append(_fc("fc", cin, 1000))
    return Workload("DenseNet121", ls)


def gemm_workload(name: str, gemms: List[tuple[int, int, int]],
                  dtype_bytes: int = 2) -> Workload:
    """Generic GEMM workload (M, K, N per layer) — used to map the assigned LM
    architectures onto the 2.5D-CrossLight accelerator model (beyond-paper)."""
    ls = []
    for i, (m, k, n) in enumerate(gemms):
        ls.append(Layer(f"{name}.g{i}", float(m) * k * n,
                        k * n * dtype_bytes, m * k * dtype_bytes,
                        m * n * dtype_bytes, k, float(m) * n))
    return Workload(name, ls)


CNN_WORKLOADS: Dict[str, Callable[[], Workload]] = {
    "DenseNet121": densenet121,
    "ResNet18": resnet18,
    "LeNet5": lenet5,
    "VGG16": vgg16,
    "MobileNetV2": mobilenet_v2,
    "EfficientNetB0": efficientnet_b0,
}

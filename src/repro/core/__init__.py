"""Layer A: analytical silicon-photonic 2.5D interposer + accelerator models
(the paper's own evaluation methodology, reproduced in JAX/NumPy)."""

from repro.core.devices import (
    DeviceLibrary,
    DEFAULT_DEVICES,
    laser_electrical_power_w,
    db_to_linear,
    linear_to_db,
)
from repro.core.topology import (
    NetworkParams,
    NetworkModel,
    sprint_bus,
    spacx_bus,
    tree_network,
    trine_network,
    electrical_mesh,
    TOPOLOGIES,
)
from repro.core.power import Traffic, NetworkReport, evaluate_network
from repro.core.planner import (
    choose_subnetworks,
    plan_gateway_activation,
    plan_collective_channels,
)
from repro.core.fabric import (
    Fabric,
    DEFAULT_FABRIC,
    FABRIC_PRESETS,
    fabrics_from_front,
    get_fabric,
    metallic_ici,
)
from repro.core.workloads import Workload, Layer, CNN_WORKLOADS, gemm_workload
from repro.core.accelerator import (
    AcceleratorConfig,
    ChipletSpec,
    AccelReport,
    monolithic_crosslight,
    crosslight_25d_siph,
    crosslight_25d_elec,
    evaluate_accelerator,
    evaluate_accelerator_batch,
    evaluate_accelerator_grid,
)
# NOTE: the `sweep` *function* is deliberately not re-exported here — it
# would shadow the `repro.core.sweep` submodule attribute on the package.
# Use `from repro.core.sweep import sweep`.
from repro.core.sweep import (
    GridSpec,
    SweepGrid,
    SweepResult,
    build_grid,
    grid_spec,
    network_columns,
    evaluate_columns,
    sweep_chunked,
    sweep_scalar_reference,
)
# `search` mirrors the note above: `pareto_search`/`codesign_pareto` are the
# one-call entry points; the full toolkit lives in `repro.core.search`.
from repro.core.search import (
    ParetoFront,
    codesign_pareto,
    frontier_configs,
    pareto_front,
    pareto_mask,
    pareto_search,
    refine_continuous,
    refine_codesign,
    refine_front,
)
from repro.core.fabric import degrade, overlapped_step_s
from repro.core.faults import (
    FaultModel,
    FaultScenario,
    FabricUnusableError,
    HEALTHY,
    AvailabilityReducer,
    availability_search,
    degraded_network_columns,
    evaluate_degraded,
    faulted_columns_fn,
)

__all__ = [n for n in dir() if not n.startswith("_")]

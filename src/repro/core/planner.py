"""Bandwidth-matching planners.

TRINE's quantitative core (paper Sec. IV): "The number of subnetworks can be
tailored to match the bandwidth that the memory can provide, ensuring that the
network bandwidth of memory aligns with the memory bandwidth.  This approach
maximizes performance without wasting network resources."

The same matching principle drives two planners here:

  * `choose_subnetworks`     -- Layer A: pick K tree subnetworks so
                                K * waveguide_BW ~= memory_BW.
  * `plan_collective_channels` -- Layer B: pick how many parallel collective
                                chunks (channels) to launch per layer so the
                                collective time matches the compute time it
                                can hide under (the TPU-mesh analog: ICI
                                bandwidth is the "memory", overlap window is
                                the "network").
  * `plan_gateway_activation` -- 2.5D-CrossLight's PCMC adaptation: fraction
                                of gateways to keep lit given a layer's
                                traffic demand.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

import numpy as np

__all__ = [
    "choose_subnetworks", "choose_subnetworks_arr",
    "plan_gateway_activation", "plan_gateway_activation_arr",
    "plan_collective_channels", "ceil_log2",
]

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.topology import NetworkParams


def _asx(xp, v):
    """float64 on the numpy path, namespace default under jax tracing."""
    return np.asarray(v, np.float64) if xp is np else xp.asarray(v)


def ceil_log2(v, xp=np):
    """Exact elementwise ceil(log2(v)) for v > 0, with zero gradient.

    XLA's `log2` is not correctly rounded at exact powers of two (e.g.
    log2(16) can evaluate to 4.000000000000001 inside a fused program), so
    `ceil(log2(v))` may overshoot by a whole stage precisely at the integral
    points the topology kernels care about.  frexp is exact by construction:
    v = m * 2**e with m in [0.5, 1), hence ceil(log2 v) = e, except at exact
    powers of two where m == 0.5 and ceil(log2 v) = e - 1.  The traced path
    wraps the input in stop_gradient — the result is piecewise constant, so
    its gradient is zero exactly like ceil(log2(.)) would give.
    """
    if xp is np:
        m, e = np.frexp(np.asarray(v, np.float64))
    else:
        import jax  # runtime import: the numpy path must stay jax-free
        m, e = xp.frexp(jax.lax.stop_gradient(xp.asarray(v)))
    return _asx(xp, xp.where(m == 0.5, e - 1, e))


def choose_subnetworks_arr(n_lambda, modulation_rate_bps, n_mem_chiplets,
                           mem_bw_bytes_per_s, n_gateways, xp=np,
                           round_mode: str = "paper"):
    """Vectorized K*: elementwise over struct-of-arrays parameter columns
    (the sweep-engine path; `choose_subnetworks` is the scalar wrapper).
    Pass ``xp=jax.numpy`` to trace it inside a jitted/differentiated kernel;
    the round/ceil quantization is piecewise-constant (zero gradient).

    `round_mode` picks the power-of-two snap for the raw K = ceil(mem/wg):
      "paper"  geometrically (log-space) nearest power of two — the paper's
               9 -> 8 choice, implemented as 2**round(log2 K).  This differs
               from the arithmetically nearest power of two (k=6 ->
               2**round(2.585) = 8, though |6-4| = |6-8|) and may round DOWN
               below the memory bandwidth,
      "cover"  next power of two up — the smallest pow2 K that actually
               covers mem_bw (never under-provisions).
    Both are clamped to the gateway count."""
    wg_bw = _asx(xp, n_lambda) * _asx(xp, modulation_rate_bps)
    mem_bw = _asx(xp, n_mem_chiplets) * _asx(xp, mem_bw_bytes_per_s) * 8.0
    k = xp.maximum(1.0, xp.ceil(mem_bw / wg_bw))
    # power-of-two so subnet trees stay balanced (paper uses 8)
    if round_mode == "paper":
        k_pow2 = 2.0 ** xp.round(xp.log2(k))
    elif round_mode == "cover":
        k_pow2 = 2.0 ** ceil_log2(k, xp)
    else:
        raise ValueError(
            f"round_mode must be 'paper' or 'cover', got {round_mode!r}")
    return xp.minimum(k_pow2, _asx(xp, n_gateways))


def choose_subnetworks(p: "NetworkParams", round_mode: str = "paper") -> int:
    """Subnetwork count K for TRINE, a power of two clamped to the gateway
    count.

    With the paper's numbers (the TRINE eval provisions against one
    100 GB/s memory interface per subnet group): 100 GB/s = 800 Gb/s,
    waveguide = 8 lambda * 12 Gb/s = 96 Gb/s  =>  raw K = ceil(800/96) = 9.
    The default ``round_mode="paper"`` reproduces the paper's choice — the
    GEOMETRICALLY (log-space) nearest power of two, 2**round(log2 K)
    (9 -> 8: "we opted for 8 subnetworks to use the maximum bandwidth
    offered by memory chiplets").  Note this is not the arithmetically
    nearest power of two (k=6 snaps up to 8, not down to 4) and it can
    round DOWN below the memory bandwidth it nominally matches.  Pass
    ``round_mode="cover"`` for the smallest power-of-two K with
    K * wg_bw >= mem_bw (next power of two up; 9 -> 16), which never
    under-provisions.
    """
    return int(choose_subnetworks_arr(
        p.n_lambda, p.modulation_rate_bps, p.n_mem_chiplets,
        p.mem_bw_bytes_per_s, p.n_gateways, round_mode=round_mode))


def plan_gateway_activation_arr(demand_bytes_per_s, max_bw_bytes_per_s,
                                n_gateways, xp=np):
    """Vectorized PCMC gateway-activation fraction (sweep/batched path).
    ``xp=jax.numpy`` makes it traceable inside the co-design grid kernel."""
    demand = _asx(xp, demand_bytes_per_s)
    maxbw = _asx(xp, max_bw_bytes_per_s)
    n = _asx(xp, n_gateways)
    frac = xp.clip(demand / xp.where(maxbw > 0, maxbw, np.inf), 0.0, 1.0)
    steps = xp.maximum(1.0, xp.ceil(frac * n))
    return xp.where(maxbw > 0, steps / n, 1.0)


def plan_gateway_activation(
    demand_bytes_per_s: float,
    max_bw_bytes_per_s: float,
    n_gateways: int,
) -> float:
    """2.5D-CrossLight PCMC gateway activation: keep the smallest fraction of
    gateways lit that still covers the traffic demand.  Returns the active
    fraction in {1/n, 2/n, ..., 1}.  Deactivated gateways are power-gated and
    their PCMC couplers divert laser power (laser scales with the fraction).
    """
    return float(plan_gateway_activation_arr(
        demand_bytes_per_s, max_bw_bytes_per_s, n_gateways))


def plan_collective_channels(
    collective_bytes: float,
    overlap_window_s: float,
    link_bw_bytes_per_s: float = None,
    max_channels: int = 8,
    min_chunk_bytes: float = 1 << 20,
    fabric=None,
) -> int:
    """Layer B bandwidth matching: number of parallel collective channels
    (chunks in flight) so transfer time ~= the compute window it hides under.

    channels = ceil(bytes / (window * bw)) -- i.e. provision exactly enough
    parallelism, never more (TRINE: "without wasting network resources").
    Clamped so chunks stay large enough to amortize per-collective latency.

    The link bandwidth may be given directly (`link_bw_bytes_per_s`) or
    derived from a network design point (`fabric` — a `core.fabric.Fabric`,
    a preset name like "trine_siph", or anything with a
    ``cross_pod_bw_bytes_per_s`` attribute); `fabric` wins when both are
    passed, since it reflects the design under evaluation.
    """
    if fabric is not None:
        link_bw_bytes_per_s = getattr(fabric, "cross_pod_bw_bytes_per_s", None)
        if link_bw_bytes_per_s is None:
            from repro.core.fabric import get_fabric  # runtime: no cycle
            link_bw_bytes_per_s = get_fabric(fabric).cross_pod_bw_bytes_per_s
    if link_bw_bytes_per_s is None:
        raise ValueError("pass link_bw_bytes_per_s or fabric")
    if link_bw_bytes_per_s <= 0:
        # a fully-degraded fabric: no channel count can carry the collective
        from repro.core.faults import FabricUnusableError  # runtime: no cycle
        raise FabricUnusableError(
            "collective cannot be scheduled: link bandwidth is zero "
            "(fabric degraded beyond use)")
    if collective_bytes <= 0:
        return 1
    need = collective_bytes / max(overlap_window_s * link_bw_bytes_per_s, 1e-30)
    ch = max(1, math.ceil(need))
    ch = min(ch, max_channels, max(1, int(collective_bytes // min_chunk_bytes)))
    return int(ch)

"""Activation-sharding context.

GSPMD propagates parameter shardings into activations; with FSDP-sharded
embeddings that makes activations flow `embed@data` and REPLICATES the batch
dimension (verified on the yi-6b dry-run: attention compute blew up 16x).
Model code therefore pins activations to batch-over-DP at stable points
(embedding output, scan-body entry, pre-loss hidden) through this context.

The context is set by the launcher/dry-run around `.lower()`; without it
(unit tests, single device) every call is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None, "dp": None, "tp": None, "seq_tp": False,
                "wire_ok": False}


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, dp_axes: Optional[Tuple[str, ...]],
                        tp_axis: Optional[str] = "model",
                        seq_tp: bool = False, wire_ok: bool = False):
    prev = dict(_STATE)
    _STATE.update(mesh=mesh, dp=dp_axes, tp=tp_axis, seq_tp=seq_tp,
                  wire_ok=wire_ok)
    try:
        yield
    finally:
        _STATE.update(prev)


def wire_active() -> bool:
    """int8 weight wire-format is only meaningful when params are fully
    sharded and compute wants them whole (ZeRO-3 / fsdp_all) — the launcher
    sets `wire_ok` there; under TP the weights must stay TP-sharded."""
    return bool(_STATE["wire_ok"]) and active()


def active() -> bool:
    return _STATE["mesh"] is not None


def constrain(x: jax.Array, spec: Tuple) -> jax.Array:
    """spec entries: 'dp' -> the context's data-parallel axes, 'tp' -> tensor
    axis, None -> unsharded."""
    if not active() or x.ndim != len(spec):
        return x
    resolved = []
    used: set = set()
    for s in spec:
        if s == "dp":
            ax = _STATE["dp"]
        elif s == "tp":
            ax = _STATE["tp"]
        else:
            ax = None
        if ax is not None:  # a mesh axis may appear at most once per spec
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            ax = axes if len(axes) > 1 else (axes[0] if axes else None)
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE["mesh"], P(*resolved)))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Batch-leading activation: (B, ...) -> B over DP axes."""
    return constrain(x, ("dp",) + (None,) * (x.ndim - 1))


def constrain_seq(x: jax.Array) -> jax.Array:
    """seq_tp (context-parallel attention): (B, S, M) -> S over the TP axis.
    No-op unless the context enables sequence-TP."""
    if not active() or not _STATE["seq_tp"] or x.ndim != 3:
        return x
    return constrain(x, ("dp", "tp", None))


def constrain_unseq(x: jax.Array) -> jax.Array:
    """Megatron-SP transition back: gather S, hand the TP axis to the MLP."""
    if not active() or not _STATE["seq_tp"] or x.ndim != 3:
        return x
    return constrain(x, ("dp", None, None))

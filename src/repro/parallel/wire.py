"""Parameter wire formats — what dtype crosses the interposer (§Perf).

The 2.5D-CrossLight interposer ships weights to photonic MAC banks at the MR
amplitude resolution (8 bits).  The TPU-mesh analog: under ZeRO-3 the
dominant collective is the per-layer parameter all-gather.  Getting the
narrow payload onto that wire took three measured iterations (all recorded
in EXPERIMENTS.md §Perf):

  1. value-level STE inside the layer (`w + stop_grad(deq(q(w)) - w)`)
     REFUTED — forces a full-precision gather of the master itself
     (collective 11.35 s -> 22.17 s on deepseek train_4k).
  2. tree-level quantize->pin->dequant at step entry REFUTED — XLA hoists
     the dequant out of the layer scan, so the scan carries (and gathers)
     the full-precision tensor; also a custom_vjp returning the int8 tensor
     gets a float0 cotangent that silently severs the weight-gradient path
     (observed as a bogus 3x compute drop).
  3. THIS design (works): scanned parameter stacks are carried through the
     scan as `{~q: int8, ~s: scale}` pairs and dequantized INSIDE the scan
     body (`dequant_subtree`, called by the model at body entry) — the same
     structure torchao/NVIDIA use for fp8 FSDP all-gathers.  Gradients flow
     through a zero-valued delta (`~d`) grafted onto each pair inside the
     differentiated function: d(loss)/d(delta) IS the straight-through
     master gradient, no custom_vjp and no float0 anywhere.  XLA folds the
     `+0` away in the primal.

Non-scanned leaves (embedding, lm_head, shared attention, encoder norm) are
transformed in-place inside the differentiated function: int8
quantize->pin->dequant through a float-boundary custom_vjp, or a bf16
cast->pin for `wire_bits=16`.

Only >=2-D float32 leaves are transformed; norm scales and biases stay f32.
Quantization scales are per-layer for stacked leaves, per-tensor otherwise
(QAT adapts; `tests/test_runtime.py::test_wire_format_training_converges`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import sharding as S

WIRE_Q, WIRE_S, WIRE_D = "~q", "~s", "~d"


def is_pair(x) -> bool:
    return isinstance(x, dict) and WIRE_Q in x


def _quantize_array(w: jax.Array, bits: int):
    """(int8 levels, f32 scale); per-layer scale for stacked (ndim>=3)."""
    qmax = 2.0 ** (bits - 1) - 1
    wf = w.astype(jnp.float32)
    axes = tuple(range(1, wf.ndim)) if wf.ndim >= 3 else tuple(range(wf.ndim))
    scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=axes, keepdims=True), 1e-8) / qmax
    q = jnp.round(wf / scale).astype(jnp.int8)
    return q, scale


def dequant_subtree(subtree, compute_dtype):
    """Model-side hook (scan-body entry): wire pairs -> plain arrays.
    The per-layer all-gather this induces moves the int8 payload."""
    def leaf(x):
        if not is_pair(x):
            return x
        wd = x[WIRE_Q].astype(compute_dtype) * x[WIRE_S].astype(compute_dtype)
        if WIRE_D in x:
            wd = wd + x[WIRE_D].astype(compute_dtype)
        return wd
    return jax.tree.map(leaf, subtree, is_leaf=is_pair)


# float-boundary custom_vjp for NON-scanned int8 leaves (embed/head/shared)
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _quant_leaf(w: jax.Array, bits: int, sharding, compute_dtype):
    return _quant_leaf_impl(w, bits, sharding, compute_dtype)


def _quant_leaf_impl(w, bits, sharding, compute_dtype):
    q, scale = _quantize_array(w, bits)
    if sharding is not None:
        q = jax.lax.with_sharding_constraint(q, sharding)
    return q.astype(compute_dtype) * scale.astype(compute_dtype)


def _quant_fwd(w, bits, sharding, compute_dtype):
    return _quant_leaf_impl(w, bits, sharding, compute_dtype), None


def _quant_bwd(bits, sharding, compute_dtype, _res, g):
    return (g.astype(jnp.float32),)   # straight-through to the f32 master


_quant_leaf.defvjp(_quant_fwd, _quant_bwd)


def _eligible(w) -> bool:
    return hasattr(w, "ndim") and w.ndim >= 2 and w.dtype == jnp.float32


class ParamWire:
    """Wire transform for one (cfg, mesh, rules).  Usage (trainer/dryrun):

        pw = ParamWire(cfg, mesh, rules, param_specs)
        def step_fn(state, batch):
            qtree = pw.quantize(state.params)          # outside AD
            def loss_v(v):
                return loss_fn(cfg, pw.graft(qtree, v), batch)
            (loss, aux), grads = value_and_grad(loss_v, has_aux=True)(
                pw.carrier(state.params))              # grads == master tree
    """

    # param subtrees that are scanned with a leading layers axis
    SCANNED_PREFIXES = (("stages",), ("encoder", "blocks"))

    def __init__(self, cfg, mesh: Mesh, rules, param_specs,
                 compute_dtype=jnp.bfloat16):
        self.bits = int(getattr(cfg, "wire_bits", 0) or 0)
        self.mesh = mesh
        self.compute_dtype = compute_dtype
        self.pspec_tree = S.tree_pspecs(param_specs, rules)

    # -- helpers ----------------------------------------------------------
    def _sharding(self, ps: P, shape) -> NamedSharding:
        return NamedSharding(self.mesh,
                             S.fix_pspec_for_shape(self.mesh, ps, shape))

    def _is_scanned(self, path) -> bool:
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        for pref in self.SCANNED_PREFIXES:
            if keys[:len(pref)] == pref:
                return True
        return False

    def _int8_pairs(self) -> bool:
        return 0 < self.bits < 16

    # -- step-level API ----------------------------------------------------
    def quantize(self, params):
        """Pairs for scanned int8-eligible stacks (leading layers axis =>
        ndim>=3); everything else passes through untouched (transformed
        differentiably in `graft`).  Call OUTSIDE value_and_grad."""
        if not self._int8_pairs():
            return params

        def leaf(path, w, ps):
            if self._is_scanned(path) and _eligible(w) and w.ndim >= 3:
                q, scale = _quantize_array(w, self.bits)
                q = jax.lax.with_sharding_constraint(
                    q, self._sharding(ps, w.shape))
                scale = jax.lax.with_sharding_constraint(
                    scale, NamedSharding(self.mesh, P()))
                return {WIRE_Q: q, WIRE_S: scale}
            return w

        return jax.tree_util.tree_map_with_path(leaf, params, self.pspec_tree)

    def carrier(self, params):
        """The differentiation variable: zeros at pair positions (the ~d
        delta), the master arrays everywhere else."""
        if not self._int8_pairs():
            return params

        def leaf(path, w):
            if self._is_scanned(path) and _eligible(w) and w.ndim >= 3:
                return jnp.zeros(w.shape, jnp.float32)
            return w

        return jax.tree_util.tree_map_with_path(leaf, params)

    def graft(self, qtree, vtree):
        """Merge carrier into the quantized tree and apply the differentiable
        transforms for non-pair leaves.  Call INSIDE value_and_grad."""
        def leaf(path, q_leaf, v_leaf, ps):
            if is_pair(q_leaf):
                return {**q_leaf, WIRE_D: v_leaf}
            w = v_leaf
            if not _eligible(w):
                return w
            sh = self._sharding(ps, w.shape)
            if self._int8_pairs():
                return _quant_leaf(w, self.bits, sh, self.compute_dtype)
            if self.bits == 16:
                return jax.lax.with_sharding_constraint(
                    w.astype(self.compute_dtype), sh)
            return w

        return jax.tree_util.tree_map_with_path(
            leaf, qtree, vtree, self.pspec_tree, is_leaf=is_pair)


def make_param_wire(cfg, mesh: Mesh, rules, param_specs,
                    compute_dtype=jnp.bfloat16) -> ParamWire:
    return ParamWire(cfg, mesh, rules, param_specs, compute_dtype)

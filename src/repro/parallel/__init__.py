from repro.parallel import sharding, collectives

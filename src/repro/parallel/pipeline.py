"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

TRINE's stage-count argument applies to pipelines too: each pipeline hop is
one interposer crossing, so the schedule below keeps exactly S-1 nearest-
neighbour hops per microbatch (a `collective_permute` ring over the `pipe`
axis) instead of any all-to-all style exchange — activations cross the slow
boundary once per stage, the minimum the dataflow admits.

Design (GPipe / praxis-style, differentiable through the schedule):

  * the layer stack is split into S contiguous stages; each stage's stacked
    params live on its own pipe-axis slice (shard_map hands each device its
    local slice),
  * the global batch is split into M microbatches; a `lax.scan` over
    M + S - 1 clock ticks drives the classic staircase — stage s works on
    microbatch t - s at tick t,
  * activations hop stage→stage with `jax.lax.ppermute`; `jax.grad`
    differentiates through the schedule (ppermute transposes to the reverse
    permutation), giving the backward staircase automatically,
  * bubble fraction = (S-1)/(M+S-1), reported by `pipeline_cost` and used by
    the planner to pick M (bandwidth matching: enough microbatches that the
    bubble is amortized, no more — "without wasting network resources").

This module is deliberately model-agnostic: `stage_fn(stage_params, x)` is
any per-stage function (tests drive it with both MLP stacks and the repo's
transformer blocks).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-stacked."""
    def f(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(f, stacked_params)


def pipeline_cost(n_stages: int, n_micro: int, step_flops: float,
                  hop_bytes: float, peak_flops: float, link_bw: float):
    """Napkin model used by tests and the planner: total ticks, bubble
    fraction, and the per-tick compute/communication times."""
    ticks = n_micro + n_stages - 1
    bubble = (n_stages - 1) / ticks
    compute_tick = step_flops / max(n_micro, 1) / peak_flops
    comm_tick = hop_bytes / link_bw
    return {"ticks": ticks, "bubble_frac": bubble,
            "tick_s": max(compute_tick, comm_tick),
            "total_s": ticks * max(compute_tick, comm_tick)}


def choose_microbatches(n_stages: int, target_bubble: float = 0.1,
                        max_micro: int = 64) -> int:
    """Bandwidth matching for the pipe: smallest M with bubble <= target."""
    m = 1
    while (n_stages - 1) / (m + n_stages - 1) > target_bubble and m < max_micro:
        m *= 2
    return m


def pipelined_apply(
    stage_fn: Callable,
    stage_params,           # pytree, leaves (S, ...) — stage dim sharded on `axis`
    x: jax.Array,           # (M, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pipe",
):
    """Run x through S pipeline stages; returns (M, mb, ...) outputs (valid
    on every device — the final ppermute broadcasts... no: outputs are
    gathered with a psum-mask so the result is replicated along `axis`).

    Correctness contract (tested): equals applying the S stage_fns
    sequentially on each microbatch.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    in_specs = (pspec_params, P())          # params stage-sharded; x replicated
    out_specs = P()

    def run(local_params, xs):
        # local_params leaves: (1, ...) — this device's stage
        local_params = jax.tree.map(lambda a: a[0], local_params)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            buf, outs = carry                      # buf: activation entering this stage
            inject = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0,
                             xs[inject].astype(buf.dtype), buf)
            h = stage_fn(local_params, x_in)
            # collect at the last stage when its microbatch is valid
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (out_idx < n_micro)
            outs = jax.lax.cond(
                valid & (stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h, jnp.clip(out_idx, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # hop to the next stage (ring; the wrap-around value is ignored)
            nbuf = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nbuf, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # replicate the result along the pipe axis (only the last stage holds it)
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)(stage_params, x)


def sequential_reference(stage_fn: Callable, stage_params, x: jax.Array):
    """Oracle: the same stages applied back-to-back (no pipelining)."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one_micro(xm):
        h = xm
        for s in range(n_stages):
            p_s = jax.tree.map(lambda a: a[s], stage_params)
            h = stage_fn(p_s, h)
        return h

    return jax.vmap(one_micro)(x)

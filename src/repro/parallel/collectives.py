"""TRINE-inspired explicit collective schedules (shard_map + jax.lax).

The paper's interposer insight mapped to mesh collectives (DESIGN.md §2):

  * `trine_all_reduce`   — stage-minimal hierarchical all-reduce: reduce-
    scatter inside the pod (one "subnetwork" stage), all-reduce across the
    tiny pod axis (the only slow-link stage), all-gather back inside the pod.
    A flat all-reduce over 512 devices rings through every device — the bus
    topology; the hierarchical schedule crosses the slow axis exactly once —
    TRINE's 2-stage tree vs the 5-stage tree / N-stage bus.

  * `compressed_all_reduce` — int8 + per-chunk scale on the cross-pod stage
    only (the bandwidth-starved link), with error-feedback residual: the PCMC
    bandwidth-adaptation analog (spend fewer "wavelengths" on low-value
    traffic).

  * `plan_channels` — re-exports the Layer-A bandwidth-matching planner for
    collective chunking (how many chunks in flight to hide a collective under
    a compute window).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core.planner import plan_collective_channels as plan_channels  # noqa: F401 — re-export


def _pad_to(x: jax.Array, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def flat_all_reduce(x: jax.Array, mesh: Mesh, axes: Tuple[str, ...] = ("pod", "data")):
    """Baseline: single-stage all-reduce over the full device set (the
    bus-topology analog)."""
    axes = tuple(a for a in axes if a in mesh.axis_names)

    def f(v):
        return jax.lax.psum(v, axes)

    return shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_vma=False)(x)


def trine_all_reduce(x: jax.Array, mesh: Mesh):
    """Hierarchical: RS(data) -> AR(pod) -> AG(data).  Cross-pod (slow) bytes
    drop by the data-axis size versus the flat schedule."""
    if "pod" not in mesh.axis_names:
        return flat_all_reduce(x, mesh, axes=("data",))
    data_n = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]

    def f(v):
        flatshape = v.shape
        flat = v.reshape(-1)
        flat, orig = _pad_to(flat, data_n)
        piece = jax.lax.psum_scatter(flat, "data", scatter_dimension=0, tiled=True)
        piece = jax.lax.psum(piece, "pod")
        full = jax.lax.all_gather(piece, "data", axis=0, tiled=True)
        return full[:orig].reshape(flatshape)

    return shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_vma=False)(x)


def _quantize_int8(v: jax.Array, chunk_elems: Optional[int] = None):
    """Symmetric int8 quantization of a 1-D tensor with per-chunk max-abs
    scales.  `chunk_elems=None` degenerates to one global scale (a single
    chunk spanning the tensor).

    Returns (q, scale): q is (n_chunks, chunk_elems) int8 (v zero-padded up
    to a chunk multiple), scale is (n_chunks,) f32.  Per-chunk scales
    localize outliers — one huge entry inflates only its own chunk's step
    size instead of the whole tensor's (the PCMC bandwidth-adaptation
    analog: spend precision where the signal is) — at a wire cost of one
    f32 per chunk.
    """
    n = v.shape[0]
    chunk = n if chunk_elems is None else max(1, min(int(chunk_elems), n))
    vp, _ = _pad_to(v, chunk)
    blocks = vp.reshape(-1, chunk)
    scale = (jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-20)
             / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    """Inverse of `_quantize_int8`: (n_chunks, chunk) int8 x (n_chunks,)
    scales -> the first `n` dequantized f32 elements."""
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]


def compressed_all_reduce(
    x: jax.Array,
    mesh: Mesh,
    residual: Optional[jax.Array] = None,
    chunk_elems: Optional[int] = None,
):
    """Hierarchical all-reduce with int8 compression on the cross-pod stage
    and error feedback.  Returns (result, new_residual).

    Intra-pod runs full precision (fast links); only the pod axis — the
    bandwidth-starved stage — carries 8-bit payloads (each pod's int8
    shard + per-chunk f32 scales are all-gathered and dequant-summed
    locally; an int8 psum would overflow and an f32 psum would put
    full-width bytes on the slow link).  `chunk_elems` sets the
    quantization granularity (None = one global scale per shard).  The
    quantization error is fed back into the next step's gradients
    (standard EF-SGD, keeps convergence).
    """
    if residual is None:
        residual = jnp.zeros_like(x)
    if "pod" not in mesh.axis_names:
        # Nothing is quantized on a single-axis mesh, but the carried
        # residual still holds gradient mass from earlier compressed steps:
        # fold it into the payload and drain it, rather than dropping it.
        out = flat_all_reduce(x + residual, mesh, axes=("data",))
        return out, jnp.zeros_like(x)

    data_n = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]

    def f(v, res):
        flatshape = v.shape
        flat = (v + res).reshape(-1)
        flat, orig = _pad_to(flat, data_n)
        piece = jax.lax.psum_scatter(flat, "data", scatter_dimension=0, tiled=True)
        q, scale = _quantize_int8(piece, chunk_elems)
        deq_local = _dequantize_int8(q, scale, piece.shape[0])
        new_res_flat = (piece - deq_local)  # local quantization error
        # cross-pod stage at int8 wire width: gather every pod's (q, scale)
        # and dequantize+sum locally
        qg = jax.lax.all_gather(q, "pod", axis=0, tiled=False)
        sg = jax.lax.all_gather(scale, "pod", axis=0, tiled=False)
        deq = (qg.astype(jnp.float32) * sg[:, :, None])
        summed = jnp.sum(deq.reshape(deq.shape[0], -1)[:, :piece.shape[0]],
                         axis=0)
        full = jax.lax.all_gather(summed, "data", axis=0, tiled=True)
        res_full = jax.lax.all_gather(new_res_flat, "data", axis=0, tiled=True)
        return (full[:orig].reshape(flatshape),
                res_full[:orig].reshape(flatshape))

    out, new_res = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False,
    )(x, residual)
    return out, new_res


def collective_bytes_estimate(n_elems: int, dtype_bytes: int, mesh: Mesh,
                              schedule: str,
                              chunk_elems: Optional[int] = None) -> dict:
    """Napkin-math model used by the planner & EXPERIMENTS.md: bytes crossing
    the slow (pod) links per device under each schedule.

    Mirrors the shard_map kernels op for op (ring-algorithm factors, the
    same padding, and — for ``trine_int8`` — the residual all-gather and
    per-chunk f32 scale payloads the kernel actually issues), so the
    estimate matches bytes measured from the compiled HLO by
    `repro.launch.hlo_analysis.analyze_hlo`; tests assert that match.
    `chunk_elems` must agree with the value passed to
    `compressed_all_reduce` (None = one global scale per shard).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pod = sizes.get("pod", 1)
    n_data = sizes.get("data", 1)
    total = n_elems * dtype_bytes
    if schedule == "flat":
        n = n_pod * n_data
        ring = 2 * (n - 1) / n * total
        # a flat ring crosses pod boundaries ~ (n_pod-1)/n_pod of its hops
        cross = ring * (n_pod - 1) / max(n_pod, 1)
        return {"total_bytes": ring, "cross_pod_bytes": cross}
    if schedule == "trine":
        rs = (n_data - 1) / n_data * total
        ar = 2 * (n_pod - 1) / n_pod * (total / n_data)
        ag = (n_data - 1) / n_data * total
        return {"total_bytes": rs + ar + ag, "cross_pod_bytes": ar}
    if schedule == "trine_int8":
        shard = -(-n_elems // n_data)          # kernel pads to a data multiple
        padded = shard * n_data * dtype_bytes
        chunk = shard if chunk_elems is None else max(1, min(int(chunk_elems),
                                                             shard))
        n_chunks = -(-shard // chunk)
        rs = (n_data - 1) / n_data * padded
        # cross-pod all-gathers: int8 shard + f32 per-chunk scales
        q_ag = (n_pod - 1) * n_chunks * chunk * 1
        scale_ag = (n_pod - 1) * n_chunks * 4
        # intra-pod all-gathers: the f32 result AND the f32 EF residual the
        # kernel gathers back to full shape
        ag = 2 * (n_data - 1) / n_data * padded
        cross = q_ag + scale_ag
        return {"total_bytes": rs + cross + ag, "cross_pod_bytes": cross}
    raise ValueError(schedule)

"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates parameters with logical axes ("embed", "heads", "ffn",
"vocab", "experts", ...); this module maps them onto mesh axes with
per-architecture and per-shape decisions, enforcing divisibility (an axis that
does not divide falls back to the next rule or to replication — e.g. yi-34b's
56 heads cannot split 16 ways, so its attention TP shards `head_dim` instead;
seamless's 256206 vocab stays replicated).

This mirrors the paper's mapping: the `data` axis is the memory-chiplet side
(SWMR parameter all-gathers / SWSR gradient reduce-scatters under FSDP); the
`model` axis is the compute-chiplet side; the `pod` axis is the cross-
subnetwork axis whose stage count the TRINE collectives minimize.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes[axes]
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def rules_for(cfg: ModelConfig, mesh: Mesh,
              strategy: Optional[str] = None) -> Dict[str, Any]:
    """Logical axis -> mesh axes (None = replicate), validated against cfg.

    Strategies (EXPERIMENTS.md §Perf):
      tp_fsdp  — Megatron TP on `model` + FSDP on fsdp_axes (baseline).
      fsdp_all — ZeRO-3 over the whole mesh; batch also spans `model`.
                 No TP activation all-reduces; params all-gather per layer.
      seq_tp   — FSDP + TP MLP, but attention runs context-parallel
                 (sequence sharded over `model`) — no head-count constraint.
    """
    strategy = strategy or cfg.parallel_strategy
    fsdp = tuple(a for a in cfg.fsdp_axes if a in mesh.axis_names) or ("data",)
    tp = "model"
    tp_n = _axis_size(mesh, tp)

    if strategy == "fsdp_all":
        full = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        return {
            "layers": None,
            "embed": full if cfg.d_model % _axis_size(mesh, full) == 0 else fsdp,
            "ffn": None, "vocab": None, "experts": None,
            "batch": None, "cache": None,
            "head_dim": None, "kv_heads": None, "heads": None,
        }

    rules: Dict[str, Any] = {
        "layers": None,
        "embed": fsdp,
        "ffn": tp,
        "vocab": tp if cfg.vocab % tp_n == 0 else None,
        "experts": tp if cfg.n_experts and cfg.n_experts % tp_n == 0 else None,
        "batch": None,   # set per-shape by batch_rules
        "cache": None,
        "head_dim": None,
        "kv_heads": None,
        "heads": None,
    }
    if strategy == "seq_tp":
        # attention weights replicated over `model`; sequence carries the TP
        return rules
    # attention TP: prefer heads; fall back to head_dim (contraction sharding)
    if cfg.n_heads % tp_n == 0:
        rules["heads"] = tp
        if cfg.n_kv_heads % tp_n == 0:
            rules["kv_heads"] = tp
    elif cfg.head_dim_ % tp_n == 0:
        rules["head_dim"] = tp
    # experts sharded over tp -> per-expert ffn must stay replicated on tp
    if rules["experts"] == tp:
        rules["ffn"] = None
    if cfg.d_ff and rules["ffn"] == tp and cfg.d_ff % tp_n != 0:
        rules["ffn"] = None
    return rules


def spec_to_pspec(axes: Optional[Tuple], rules: Dict[str, Any]) -> P:
    if axes is None:
        return P()
    out = []
    used: set = set()

    def usable(m):
        if m is None:
            return None
        ms = (m,) if isinstance(m, str) else tuple(m)
        if any(x in used for x in ms):
            return None
        used.update(ms)
        return m

    for ax in axes:
        m = usable(rules.get(ax)) if ax is not None else None
        out.append(m)
    return P(*out)


def is_axes_leaf(x) -> bool:
    """A spec leaf is None or a tuple of axis names/None — NOT an arbitrary
    tuple (TrainState is a NamedTuple and must be recursed into)."""
    return x is None or (
        isinstance(x, tuple)
        and not hasattr(x, "_fields")
        and all(e is None or isinstance(e, str) for e in x)
    )


def tree_pspecs(spec_tree, rules):
    return jax.tree.map(
        lambda axes: spec_to_pspec(axes, rules),
        spec_tree,
        is_leaf=is_axes_leaf,
    )


def tree_shardings(mesh: Mesh, spec_tree, rules):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        tree_pspecs(spec_tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# per-shape activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh, global_batch: int,
               strategy: str = "tp_fsdp") -> Optional[Tuple[str, ...]]:
    """Largest prefix of the data-parallel axes that divides the batch
    (fsdp_all spans the model axis too)."""
    names = (("pod", "data", "model") if strategy == "fsdp_all"
             else ("pod", "data"))
    cand = [a for a in names if a in mesh.axis_names]
    chosen: Tuple[str, ...] = ()
    for take in range(len(cand), 0, -1):
        axes = tuple(cand[:take])
        if global_batch % _axis_size(mesh, axes) == 0:
            chosen = axes
            break
    return chosen or None


def batch_pspec(mesh: Mesh, batch_leaf_ndim: int, global_batch: int,
                seq_shard: bool = False) -> P:
    ba = batch_axes(mesh, global_batch)
    return P(ba, *([None] * (batch_leaf_ndim - 1)))


def train_batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_spec,
                          strategy: str = None):
    """Shard every batch leaf on its batch dimension (positions leaf has
    leading 3 for M-RoPE)."""
    strategy = strategy or cfg.parallel_strategy

    def leaf_sharding(leaf):
        shape = leaf.shape
        if len(shape) >= 3 and shape[0] == 3:  # (3, B, S) M-RoPE positions
            b = shape[1]
            ps = P(None, batch_axes(mesh, b, strategy),
                   *([None] * (len(shape) - 2)))
        else:
            b = shape[0]
            ps = P(batch_axes(mesh, b, strategy),
                   *([None] * (len(shape) - 1)))
        return NamedSharding(mesh, ps)

    return jax.tree.map(leaf_sharding, batch_spec)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_spec, global_batch: int,
                    rules: Dict[str, Any]):
    """Decode caches.  Batch shards over (pod, data) when divisible; the KV
    head dim shards over `model` when divisible, otherwise the cache LENGTH
    takes the leftover axes (sequence-parallel / flash-decoding: GSPMD emits
    the partial-softmax renormalization collectives).  Every leaf is then
    divisibility-checked (`enforce_divisibility`) since recurrent-state caches
    have batch*heads leading dims."""
    tp_n = _axis_size(mesh, "model")
    ba = batch_axes(mesh, global_batch)
    kv_ok = cfg.n_kv_heads % tp_n == 0
    seq_axes = []
    if ba is None:
        seq_axes += [a for a in ("pod", "data") if a in mesh.axis_names]
    if not kv_ok:
        seq_axes.append("model")
    local_rules = dict(rules)
    local_rules["batch"] = ba
    local_rules["kv_heads"] = "model" if kv_ok else None
    local_rules["cache"] = tuple(seq_axes) if seq_axes else None
    return tree_shardings(mesh, cache_spec, local_rules)


def fix_pspec_for_shape(mesh: Mesh, ps: P, shape) -> P:
    """Drop mesh axes from any dim of `ps` they do not divide (single-leaf
    version of `enforce_divisibility`, usable at trace time)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = list(ps) + [None] * (len(shape) - len(ps))
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        keep, n = [], 1
        for a in axes:
            if dim % (n * sizes[a]) == 0:
                keep.append(a)
                n *= sizes[a]
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def enforce_divisibility(sharding_tree, shape_tree):
    """Drop mesh axes from any dim they do not divide (per-leaf fixup for
    odd-sized leading dims like B*H recurrent states)."""
    def fix(sh: NamedSharding, leaf):
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        mesh = sh.mesh
        out = []
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                out.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            keep = []
            n = 1
            for a in axes:
                sz = dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                if dim % (n * sz) == 0:
                    keep.append(a)
                    n *= sz
            out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, sharding_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, NamedSharding))

"""Fault-tolerant checkpointing (no orbax — built from scratch).

  * step-atomic: write to `step_XXXX.tmp/`, fsync, rename — a crash mid-write
    never corrupts the latest checkpoint,
  * content-verified: per-leaf SHA1 manifest checked on restore,
  * topology-elastic: leaves are stored as FULL logical arrays (gathered from
    whatever sharding they had), so a checkpoint taken on N devices restores
    onto any M-device mesh — restore just applies the new shardings
    (`device_put` with NamedSharding).  This is the elastic-scaling path:
    lose a pod, re-mesh, restore, continue.
  * retention: keep the newest `keep` checkpoints.

On a multi-host deployment each host would write its addressable shards and
the manifest would key on (leaf, shard); the single-host container collapses
that to full arrays — interface kept identical.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> list:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in leaves]


def save(directory: str | Path, step: int, tree: Any, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "leaves": {}}
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        digest = hashlib.sha1((tmp / fn).read_bytes()).hexdigest()
        manifest["leaves"][name] = {
            "file": fn, "dtype": str(arr.dtype), "shape": list(arr.shape),
            "sha1": digest,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    dirfd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def retained_steps(directory: str | Path) -> list:
    """Ascending step numbers of every retained (non-.tmp) checkpoint."""
    directory = Path(directory)
    if not directory.exists():
        return []
    return sorted(int(d.name.split("_")[1]) for d in directory.iterdir()
                  if d.is_dir() and d.name.startswith("step_")
                  and not d.name.endswith(".tmp"))


def latest_step(directory: str | Path) -> Optional[int]:
    steps = retained_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str | Path, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`; apply `shardings` (same pytree
    structure of NamedSharding / None) — the elastic re-shard point."""
    ck = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((ck / "manifest.json").read_text())
    names = [n for n, _ in _leaf_paths(like)]
    assert set(names) == set(manifest["leaves"].keys()), (
        "checkpoint/model structure mismatch")

    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: x is None or hasattr(x, "spec"))
                    if shardings is not None else [None] * len(names))
    out_leaves = []
    for (name, _), sh in zip(_leaf_paths(like), shard_leaves):
        meta = manifest["leaves"][name]
        # one read per leaf: hash and decode the same buffer
        raw = (ck / meta["file"]).read_bytes()
        if hashlib.sha1(raw).hexdigest() != meta["sha1"]:
            raise IOError(f"checkpoint corruption in {name}")
        arr = np.load(io.BytesIO(raw), allow_pickle=False)
        out_leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
    return jax.tree.unflatten(jax.tree.structure(like), out_leaves)


def restore_latest_valid(directory: str | Path, like: Any,
                         shardings: Any = None
                         ) -> Optional[Tuple[Any, int]]:
    """Restore the newest retained checkpoint that verifies, walking back
    through older retained steps when the latest is corrupt or truncated
    (bad SHA1, missing manifest, undecodable leaf).  Bad checkpoint
    directories are deleted so retries and retention don't keep tripping on
    them.  Returns (state, step), or None when nothing restorable exists."""
    directory = Path(directory)
    for step in reversed(retained_steps(directory)):
        try:
            return restore(directory, step, like, shardings), step
        except (OSError, EOFError, ValueError) as e:
            # OSError covers the SHA1 IOError + missing files;
            # ValueError/EOFError cover truncated/undecodable npy payloads
            bad = directory / f"step_{step:08d}"
            print(f"[checkpoint] dropping corrupt {bad.name}: {e}")
            shutil.rmtree(bad, ignore_errors=True)
    return None

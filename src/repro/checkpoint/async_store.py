"""Asynchronous checkpointing: snapshot-to-host synchronously (cheap —
device_get of the sharded state), write + fsync + rename in a background
thread so the train loop never blocks on disk.  Same on-disk format and
atomicity guarantees as `store.save`; `store.restore` reads both.

At 1000-node scale the write time of a multi-TB checkpoint exceeds a train
step by orders of magnitude — async checkpointing is what makes frequent
(low-RPO) checkpoints affordable.
"""

from __future__ import annotations

import concurrent.futures as _fut
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint import store


class AsyncCheckpointer:
    """One background writer; `save()` returns immediately after the host
    snapshot.  A second save while a write is in flight blocks until the
    previous write lands (ordering guarantee — checkpoints commit in step
    order)."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._pool = _fut.ThreadPoolExecutor(max_workers=1,
                                             thread_name_prefix="ckpt")
        self._pending: Optional[_fut.Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any) -> _fut.Future:
        # synchronous host snapshot: the state can be donated/mutated the
        # moment this returns
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            if self._pending is not None:
                self._pending.result()   # commit order
            self._pending = self._pool.submit(
                store.save, self.directory, step, host_tree, self.keep)
            return self._pending

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)

"""Memory-mapped binary token corpus source (production data path).

A corpus is a flat little-endian uint16/uint32 token file (the standard
"packed tokens" format).  Sampling is deterministic in (step, host): every
host computes its disjoint slice of the global batch from the step index
alone — the same step-indexed determinism contract as `SyntheticLM`, so
checkpoint-resume replays identical batches and straggler/failure handling
composes unchanged.

Sequences are drawn strided across the corpus with a per-step deterministic
offset (golden-ratio hop) so consecutive steps cover the corpus without
shuffling state to checkpoint.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.data.pipeline import DataConfig


@dataclasses.dataclass
class TokenFileSource:
    cfg: object                    # ModelConfig (vocab clamp)
    data: DataConfig
    path: str | Path
    dtype: str = "uint16"
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        need = self.data.seq_len + 1
        self._n_starts = max(1, len(self._tokens) - need)
        assert self.data.global_batch % self.host_count == 0
        self._local_b = self.data.global_batch // self.host_count

    def __len__(self):
        return len(self._tokens)

    def batch_at(self, step: int):
        """Deterministic (step, host)-indexed batch: {tokens, labels}."""
        need = self.data.seq_len + 1
        # golden-ratio hop gives full-period coverage of start offsets
        base = (step * 2654435761) % self._n_starts
        rows = []
        for i in range(self._local_b):
            g = self.host_index * self._local_b + i
            start = (base + g * (self._n_starts // max(
                self.data.global_batch, 1) + 1)) % self._n_starts
            rows.append(np.asarray(self._tokens[start:start + need],
                                   dtype=np.int32))
        arr = np.stack(rows)
        arr = np.minimum(arr, self.cfg.vocab - 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

"""Deterministic synthetic data pipeline with prefetch and straggler hooks.

Production shape without external deps:
  * `SyntheticLM` — seeded, step-indexed token streams (same step -> same
    batch, independent of restart point: checkpoint/resume reproducibility).
  * `Prefetcher` — background-thread double buffering (host-side overlap of
    data with compute; on TPU this is the host->device transfer window).
  * `DeadlineMonitor` — straggler mitigation: batches that miss the step
    deadline are dropped and accounted (the synchronous-SGD batch-drop
    strategy); statistics feed the elastic controller in repro.runtime.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


class SyntheticLM:
    """Zipf-ish synthetic token stream: step-indexed, host-shardable."""

    def __init__(self, cfg: ModelConfig, data: DataConfig,
                 host_index: int = 0, host_count: int = 1):
        assert data.global_batch % host_count == 0
        self.cfg, self.data = cfg, data
        self.host_index, self.host_count = host_index, host_count
        self.per_host = data.global_batch // host_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.data.seed * 1_000_003 + step) * 97 + self.host_index)
        b, s, v = self.per_host, self.data.seq_len, self.cfg.vocab
        # Zipf-like marginal over a permuted vocab; documents of random length
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = (ranks % (v - 2)) + 2
        toks = toks.astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.mrope:
            pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None, None],
                                  (3, b, s)).copy()
            batch["positions"] = pos
        if self.cfg.frontend == "vision":
            rngf = np.random.default_rng(step + 7)
            batch["pixel_embeds"] = rngf.standard_normal(
                (b, min(256, s), self.cfg.d_model), dtype=np.float32)
        if self.cfg.encoder_layers:
            rngf = np.random.default_rng(step + 13)
            batch["enc_embeds"] = rngf.standard_normal(
                (b, max(1, s // 4), self.cfg.d_model), dtype=np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering)."""

    def __init__(self, source: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._src = source
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._src:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    dropped: int = 0
    deadline_s: float = 0.0

    @property
    def drop_rate(self) -> float:
        return self.dropped / max(1, self.steps)


class DeadlineMonitor:
    """Synchronous-SGD straggler mitigation by deadline: a host that cannot
    deliver its shard by `deadline_s` has its microbatch dropped for that step
    (gradient renormalized by the survivor count).  On this CPU container the
    delivery time is simulated by the caller; the policy + accounting is the
    deliverable."""

    def __init__(self, deadline_s: float):
        self.stats = StragglerStats(deadline_s=deadline_s)

    def admit(self, delivery_s: float) -> bool:
        self.stats.steps += 1
        if delivery_s > self.stats.deadline_s:
            self.stats.dropped += 1
            return False
        return True

    def survivor_scale(self, n_hosts: int, n_dropped: int) -> float:
        """Gradient rescale so the expectation stays unbiased."""
        alive = max(1, n_hosts - n_dropped)
        return n_hosts / alive

"""JAX version compatibility shims.

The repo targets the modern ``jax.shard_map`` API (jax >= 0.6: top-level
export, ``check_vma=``, ``axis_names=``).  On 0.4.x the callable lives at
``jax.experimental.shard_map.shard_map`` with the older keyword surface
(``check_rep=``, ``auto=``).  Import ``shard_map`` from here instead of from
``jax`` so the suite runs on either line:

    from repro.compat import shard_map

The wrapper accepts the modern keywords everywhere and translates them for
the experimental implementation:

  check_vma=X   -> check_rep=X
  axis_names=S  -> auto=frozenset(mesh.axis_names) - S   (manual-over-S)
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

__all__ = ["shard_map", "JAX_HAS_NATIVE_SHARD_MAP"]


def _resolve():
    """Return (impl, is_modern).  Modern = accepts check_vma/axis_names."""
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl, True
    from jax.experimental.shard_map import shard_map as impl  # jax 0.4.x
    params = inspect.signature(impl).parameters
    return impl, "check_vma" in params


_IMPL, JAX_HAS_NATIVE_SHARD_MAP = _resolve()


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = True, axis_names: Any = None, **kw):
    """Version-portable ``shard_map`` (modern keyword surface)."""
    if JAX_HAS_NATIVE_SHARD_MAP:
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _IMPL(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=check_vma, **kw)
    # jax 0.4.x experimental surface: check_rep / auto
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _IMPL(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 check_rep=check_vma, **kw)

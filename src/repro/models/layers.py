"""Building blocks for every assigned architecture family.

Pure functions over explicit parameter dicts.  Each `init_*` returns
`(params, specs)` where `specs` mirrors `params` with logical-axis tuples
(consumed by `repro.parallel.sharding`).  Each `apply_*` takes `(cfg, params,
x, ...)`, casts to the compute dtype, and is scan/remat friendly.

Every matmul routes through `linear()`, which optionally applies the
photonic-MAC QAT numerics (2.5D-CrossLight broadcast-and-weight quantization)
— the paper's compute engine as a first-class model feature.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.parallel import actx

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init / linear helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axes=(0,), dtype=jnp.float32):
    fan_in = max(1, math.prod(shape[a] for a in in_axes))
    return (jax.random.normal(key, shape, dtype) / math.sqrt(fan_in)).astype(dtype)


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def linear(cfg: ModelConfig, w: jax.Array, x: jax.Array) -> jax.Array:
    """x (..., K) @ w (K, ...out) with optional photonic-MAC numerics."""
    k = w.shape[0]
    out_shape = w.shape[1:]
    if cfg.use_photonic_mac:
        x2 = x.reshape(-1, k)
        w2 = w.reshape(k, -1)
        y = ops.photonic_matmul(x2, w2, cfg.photonic_bits, cfg.use_kernels)
        return y.reshape(*x.shape[:-1], *out_shape).astype(x.dtype)
    # NOTE: wire formats (bf16/int8 param all-gathers) are applied at TREE
    # level by `repro.parallel.wire` at step entry — an in-layer constraint
    # here cannot know the leaf's sharded spec and measurably backfires
    # (EXPERIMENTS.md §Perf, deepseek iter.3a).
    y = jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
    )
    return y


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig) -> jax.Array:
    dh = cfg.head_dim_
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x (B, S, H, Dh). positions (B, S) int32, or (3, B, S) for M-RoPE
    (temporal/height/width streams; equal streams == standard RoPE)."""
    dh = x.shape[-1]
    freqs = rope_freqs(cfg)  # (Dh/2,)
    if cfg.mrope and positions.ndim == 3:
        # split rotary dims into 3 contiguous sections (t, h, w)
        n = dh // 2
        s0, s1 = n - 2 * (n // 3), n // 3  # t gets the remainder
        sect = jnp.concatenate([
            jnp.zeros((s0,), jnp.int32),
            jnp.ones((s1,), jnp.int32),
            jnp.full((n - s0 - s1,), 2, jnp.int32),
        ])
        pos = positions.astype(jnp.float32)  # (3, B, S)
        angles = pos[..., None] * freqs[None, None, None, :]  # (3, B, S, n)
        angle = jnp.take_along_axis(
            jnp.moveaxis(angles, 0, -1), sect[None, None, :, None], axis=-1
        )[..., 0]  # (B, S, n)
    else:
        angle = positions.astype(jnp.float32)[..., None] * freqs[None, None, :]
    cos = jnp.cos(angle)[:, :, None, :]  # (B, S, 1, n)
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (m, h, dh)),
        "wk": _dense_init(k2, (m, hk, dh)),
        "wv": _dense_init(k3, (m, hk, dh)),
        "wo": _dense_init(k4, (h, dh, m), in_axes=(0, 1)),
        "norm": jnp.zeros((m,)),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
        "norm": (None,),
    }
    return p, s


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array):
    b, s, m = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = linear(cfg, p["wq"].reshape(m, h * dh), x).reshape(b, s, h, dh)
    k = linear(cfg, p["wk"].reshape(m, hk * dh), x).reshape(b, s, hk, dh)
    v = linear(cfg, p["wv"].reshape(m, hk * dh), x).reshape(b, s, hk, dh)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    return q, k, v


def apply_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: int = 0,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,
    return_kv: bool = False,
):
    """Pre-norm attention block with residual.

    Train/prefill: cache is None -> full-sequence attention (flash kernel or
    reference).  Decode: cache {'k','v'} (B,Hk,Sc,Dh) + cache_pos scalar ->
    one-step attention over the cache.
    """
    b, s, m = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cache is None:
        x = actx.constrain_seq(x)  # seq_tp: context-parallel attention
    xn = rms_norm(x, p["norm"])
    q, k, v = _qkv(cfg, p, xn, positions)
    q = jnp.moveaxis(q, 2, 1)  # (B,H,S,Dh)
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)

    new_cache = None
    if cache is None:
        out = ops.attention(q, k, v, causal, window, None, 0, cfg.use_kernels)
    elif s > 1:
        # prefill: full-sequence attention, then materialize the cache
        out = ops.attention(q, k, v, causal, window, None, 0, cfg.use_kernels)
        wlen = cache["k"].shape[2]
        kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        if s >= wlen:  # windowed (or exact-length) cache: keep the last wlen
            new_cache = {"k": kd[:, :, s - wlen:], "v": vd[:, :, s - wlen:]}
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kd, cache_pos, axis=2),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vd, cache_pos, axis=2),
            }
    else:
        # single-step decode; windowed caches roll once full.  cache_pos may
        # be a scalar (lockstep batch) or a (B,) vector (continuous batching:
        # each slot decodes at its own position).
        wlen = cache["k"].shape[2]
        kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        pos_b = jnp.broadcast_to(jnp.asarray(cache_pos), (b,))

        def upd1(c, new, pos):
            rolled = jax.lax.cond(
                pos >= wlen,
                lambda a: jnp.roll(a, -1, axis=1),
                lambda a: a,
                c)                                     # (Hk, W, Dh) per example
            slot = jnp.minimum(pos, wlen - 1)
            return jax.lax.dynamic_update_slice_in_dim(rolled, new, slot, axis=1)

        upd = jax.vmap(upd1)
        ck, cv = upd(cache["k"], kd, pos_b), upd(cache["v"], vd, pos_b)
        new_cache = {"k": ck, "v": cv}
        pos_eff = jnp.minimum(
            cache_pos, wlen - 1)                       # scalar or (B,)
        out = decode_attention(q, ck, cv, pos_eff, window=0)

    out = jnp.moveaxis(out.astype(x.dtype), 1, 2).reshape(b, s, h * dh)
    y = linear(cfg, p["wo"].reshape(h * dh, m), out)
    res = x + y
    if return_kv:
        return res, new_cache, (k, v)
    return res, new_cache


def decode_attention(q, k, v, pos, *, window: int = 0):
    """One-step (or few-step) attention over a statically-shaped KV cache.
    q (B,H,Sq,Dh); k,v (B,Hk,Sc,Dh); pos = absolute position of the last
    query — a scalar, or a (B,) vector for continuous batching.
    GSPMD shards Sc; softmax renormalizes globally (flash-decoding style)."""
    b, h, sq, dh = q.shape
    hk, sc = k.shape[1], k.shape[2]
    group = h // hk
    qg = q.reshape(b, hk, group, sq, dh).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) * dh ** -0.5
    kpos = jnp.arange(sc)
    pos = jnp.asarray(pos)
    qpos = (pos[:, None] if pos.ndim else pos) - jnp.arange(sq)[::-1]  # (B?,Sq)
    valid = kpos <= qpos[..., None]                    # (Sq,Sc) or (B,Sq,Sc)
    if window > 0:
        valid &= kpos > qpos[..., None] - window
    mask = valid[:, None, None] if pos.ndim else valid[None, None, None]
    s = jnp.where(mask, s, -1e30)
    pm = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", pm, v.astype(jnp.float32))
    return out.reshape(b, h, sq, dh)


def init_cross_attention(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    return init_attention(cfg, key)


def apply_cross_attention(cfg: ModelConfig, p: Params, x, enc_out, positions):
    """Decoder cross-attention: queries from x, keys/values from enc_out."""
    b, s, m = x.shape
    se = enc_out.shape[1]
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    xn = rms_norm(x, p["norm"])
    q = linear(cfg, p["wq"].reshape(m, h * dh), xn).reshape(b, s, h, dh)
    k = linear(cfg, p["wk"].reshape(m, hk * dh), enc_out).reshape(b, se, hk, dh)
    v = linear(cfg, p["wv"].reshape(m, hk * dh), enc_out).reshape(b, se, hk, dh)
    out = ops.attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        False, 0, None, 0, cfg.use_kernels)
    out = jnp.moveaxis(out.astype(x.dtype), 1, 2).reshape(b, s, h * dh)
    return x + linear(cfg, p["wo"].reshape(h * dh, m), out)


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": _dense_init(k1, (m, f)),
        "wg": _dense_init(k2, (m, f)),
        "wo": _dense_init(k3, (f, m)),
        "norm": jnp.zeros((m,)),
    }
    s = {"wi": ("embed", "ffn"), "wg": ("embed", "ffn"),
         "wo": ("ffn", "embed"), "norm": (None,)}
    return p, s


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    x = actx.constrain_unseq(x)  # seq_tp: hand the TP axis back to the MLP
    xn = rms_norm(x, p["norm"])
    g = jax.nn.silu(linear(cfg, p["wg"], xn).astype(jnp.float32)).astype(x.dtype)
    h = linear(cfg, p["wi"], xn) * g
    return x + linear(cfg, p["wo"], h)


def init_moe(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": _dense_init(k1, (m, e)),
        "wi": _dense_init(k2, (e, m, f), in_axes=(1,)),
        "wg": _dense_init(k3, (e, m, f), in_axes=(1,)),
        "wo": _dense_init(k4, (e, f, m), in_axes=(1,)),
        "norm": jnp.zeros((m,)),
    }
    s = {"router": ("embed", None),
         "wi": ("experts", "embed", "ffn"), "wg": ("experts", "embed", "ffn"),
         "wo": ("experts", "ffn", "embed"), "norm": (None,)}
    return p, s


def _moe_index_path(cfg: ModelConfig, p: Params, xn, idx, gate_vals, keep,
                    pos_ce, cap: int):
    """Index-based MoE dispatch body (may run inside a batch-manual
    shard_map — all shapes here are per-shard local)."""
    b, s, m = xn.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = xn.dtype
    t_e = idx.transpose(0, 2, 1).reshape(b, k * s)             # expert per choice
    keep_t = jnp.sum(keep, axis=-1) > 0                        # (B,kS)
    c_t = pos_ce
    s_t = jnp.broadcast_to(
        jnp.tile(jnp.arange(s, dtype=jnp.int32), k)[None], (b, k * s))
    dump = jnp.where(keep_t, c_t, cap)                         # dropped -> dump slot
    flat_slot = t_e * (cap + 1) + dump                         # (B,kS)

    def scat(vals, dtype):
        def one(fs, v):
            return jnp.zeros((e * (cap + 1),), dtype).at[fs].set(v)
        return jax.vmap(one)(flat_slot, vals)                  # (B, E*(cap+1))

    slot_token = scat(s_t, jnp.int32).reshape(b, e, cap + 1)[..., :cap]
    slot_valid = scat(keep_t, jnp.bool_).reshape(b, e, cap + 1)[..., :cap]
    xe = jnp.take_along_axis(
        xn, slot_token.reshape(b, e * cap)[..., None], axis=1)
    xe = jnp.where(slot_valid.reshape(b, e * cap)[..., None], xe, 0)
    xe = jnp.moveaxis(xe.reshape(b, e, cap, m).astype(dt), 0, 1)  # (E,B,C,M)
    gme = jax.nn.silu(jnp.einsum("ebcm,emf->ebcf", xe, p["wg"].astype(dt))
                      .astype(jnp.float32)).astype(dt)
    hme = jnp.einsum("ebcm,emf->ebcf", xe, p["wi"].astype(dt)) * gme
    ye = jnp.einsum("ebcf,efm->ebcm", hme, p["wo"].astype(dt))
    ye_b = jnp.moveaxis(ye, 0, 1).reshape(b, e * cap, m)       # (B,E*C,M)
    flat_ec = t_e * cap + jnp.minimum(c_t, cap - 1)            # (B,kS)
    yt = jnp.take_along_axis(ye_b, flat_ec[..., None], axis=1)
    yt = jnp.where(keep_t[..., None], yt, 0)
    gate_t = gate_vals.transpose(0, 2, 1).reshape(b, k * s)    # choices-major
    return jnp.sum((yt * gate_t[..., None].astype(yt.dtype))
                   .reshape(b, k, s, m), axis=1)


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array):
    """Top-k routed MoE with capacity (GShard-style dispatch/combine einsums;
    expert dim shards over the mesh for expert parallelism).  Returns
    (y, aux_loss)."""
    b, s, m = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * s * k / e))

    xn = rms_norm(x, p["norm"])
    logits = linear(cfg, p["router"], xn).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                   # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)         # (B,S,k,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, k * s, e)   # choices-major
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat            # (B,k*S,E)
    keep = (pos_in_expert < cap) * flat
    pos_ce = jnp.einsum("bte,bte->bt", pos_in_expert, keep)    # (B,k*S)
    if cfg.moe_dispatch != "index":
        disp_flat = keep[..., None] * jax.nn.one_hot(pos_ce, cap)[:, :, None, :]  # (B,k*S,E,C)
        dispatch = disp_flat.reshape(b, k, s, e, cap).transpose(0, 2, 1, 3, 4)
        combine = dispatch * gate_vals[..., None, None]        # (B,S,k,E,C)
        dispatch = dispatch.sum(axis=2)                        # (B,S,E,C)
        combine = combine.sum(axis=2)

    if cfg.moe_dispatch == "index":
        # gather/scatter dispatch: identical capacity-drop rule, but tokens
        # move by indexing instead of one-hot matmuls — removes the
        # O(B·S·E·cap·M) dispatch/combine FLOPs (quadratic in S since
        # cap ∝ S) that dominate the einsum path at long sequence.
        # Under a mesh the index math runs inside a shard_map that is MANUAL
        # on the batch axes (gathers/scatters stay device-local — GSPMD's
        # gather partitioner would otherwise replicate them, measured 258 GB
        # of all-to-all) and AUTO on the model axis (expert TP still GSPMD).
        args = (xn, idx, gate_vals, keep, pos_ce.astype(jnp.int32))
        if actx.active() and actx._STATE["dp"]:
            mesh, dp = actx._STATE["mesh"], actx._STATE["dp"]
            dpt = (dp,) if isinstance(dp, str) else tuple(dp)
            from jax.sharding import PartitionSpec as _P
            b3 = _P(dpt, None, None)
            b2 = _P(dpt, None)
            y = shard_map(
                lambda pw, xn_, idx_, gv_, kp_, pc_: _moe_index_path(
                    cfg, pw, xn_, idx_, gv_, kp_, pc_, cap),
                mesh=mesh,
                in_specs=(_P(), b3, b3, b3, b3, b2),
                out_specs=b3,
                axis_names=set(dpt),
                check_vma=False,
            )(p, *args)
        else:
            y = _moe_index_path(cfg, p, *args, cap)
        y = y.astype(x.dtype)
    else:
        xe = jnp.einsum("bsec,bsm->ebcm", dispatch.astype(x.dtype), xn)
        gme = jax.nn.silu(jnp.einsum("ebcm,emf->ebcf", xe, p["wg"].astype(x.dtype))
                          .astype(jnp.float32)).astype(x.dtype)
        hme = jnp.einsum("ebcm,emf->ebcf", xe, p["wi"].astype(x.dtype)) * gme
        ye = jnp.einsum("ebcf,efm->ebcm", hme, p["wo"].astype(x.dtype))
        y = jnp.einsum("bsec,ebcm->bsm", combine.astype(x.dtype), ye)

    # load-balance aux loss (Switch) + router z-loss
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = onehot.sum(axis=2).mean(axis=(0, 1))                  # fraction routed
    aux = e * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return x + y, aux


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 hybrid)
# ---------------------------------------------------------------------------


def init_mamba(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m, din, n, hm = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * din + 2 * n + hm  # [z, x, B, C, dt]
    p = {
        "in_proj": _dense_init(k1, (m, proj_out)),
        "conv": _dense_init(k2, (cfg.conv_width, din)) * 0.1,
        "A_log": jnp.zeros((hm,)) + math.log(0.5),
        "D": jnp.ones((hm,)),
        "dt_bias": jnp.zeros((hm,)),
        "out_proj": _dense_init(k3, (din, m)),
        "norm": jnp.zeros((m,)),
        "gate_norm": jnp.zeros((din,)),
    }
    s = {"in_proj": ("embed", "ffn"), "conv": (None, "ffn"),
         "A_log": (None,), "D": (None,), "dt_bias": (None,),
         "out_proj": ("ffn", "embed"), "norm": (None,), "gate_norm": (None,)}
    return p, s


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x (B,L,C), w (W,C).  state (B,W-1,C) or None.
    Returns (y, new_state)."""
    b, l, c = x.shape
    wlen = w.shape[0]
    if state is None:
        state = jnp.zeros((b, wlen - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, L+W-1, C)
    y = sum(xp[:, i:i + l, :] * w[i][None, None, :] for i in range(wlen))
    new_state = xp[:, -(wlen - 1):, :] if wlen > 1 else state
    return y, new_state


def apply_mamba(cfg: ModelConfig, p: Params, x: jax.Array,
                cache: Optional[Params] = None):
    """Mamba2-style selective SSM block (scalar per-head decay, matrix state).
    Train: chunked scan kernel.  Decode: single-step recurrence on cached
    state.  Returns (y, new_cache)."""
    b, l, m = x.shape
    din, n, hm, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    xn = rms_norm(x, p["norm"])
    proj = linear(cfg, p["in_proj"], xn)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv"].astype(xs.dtype), conv_state)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,L,Hm)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, None, :] * dt)            # (B,L,Hm)
    xh = xs.reshape(b, l, hm, pdim)

    if cache is None or l > 1:
        # (B,L,Hm,P) -> (B*Hm, L, P); decay (B*Hm, L); b/c shared across heads
        # big scan operands stay in the compute dtype (bf16) — the chunked
        # SSD path accumulates in f32 via preferred_element_type, and the
        # decay math (log/cumsum) is always f32 inside the scan.  Halves the
        # scan's HBM traffic (§Perf zamba2 iteration 4).
        sdt = compute_dtype(cfg)
        xf = jnp.moveaxis(xh, 2, 1).reshape(b * hm, l, pdim).astype(sdt)
        af = jnp.moveaxis(a, 2, 1).reshape(b * hm, l)
        bf = jnp.repeat(bmat.astype(sdt), hm, axis=0).reshape(b * hm, l, n)
        cf = jnp.repeat(cmat.astype(sdt), hm, axis=0).reshape(b * hm, l, n)
        y = ops.ssm(xf, af, bf, cf, cfg.use_kernels)
        y = jnp.moveaxis(y.reshape(b, hm, l, pdim), 1, 2)            # (B,L,Hm,P)
        new_cache = None
        if cache is not None:  # prefill: also materialize the final state
            log_a = jnp.log(jnp.maximum(a, 1e-37))                   # (B,L,Hm)
            cum = jnp.cumsum(log_a, axis=1)
            w = jnp.exp(cum[:, -1:, :] - cum)                        # Π_{r>s} a_r
            s_fin = jnp.einsum("blh,blhp,bln->bhpn", w,
                               xh.astype(jnp.float32),
                               bmat.astype(jnp.float32))
            new_cache = {"state": s_fin, "conv": new_conv}
    else:
        s_prev = cache["state"]                                      # (B,Hm,P,N)
        a1 = a[:, 0]                                                 # (B,Hm)
        upd = jnp.einsum("bhp,bn->bhpn", xh[:, 0].astype(jnp.float32),
                         bmat[:, 0].astype(jnp.float32))
        s_new = a1[..., None, None] * s_prev + upd
        y = jnp.einsum("bhpn,bn->bhp", s_new, cmat[:, 0].astype(jnp.float32))
        y = y[:, None]                                               # (B,1,Hm,P)
        new_cache = {"state": s_new, "conv": new_conv}

    y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32))
    y = y.reshape(b, l, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["gate_norm"])
    return x + linear(cfg, p["out_proj"], y), new_cache


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m, dh, h = cfg.d_model, cfg.head_dim_, cfg.n_heads
    din = h * dh
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wqkv": _dense_init(k1, (m, 3 * din)),
        "wif": _dense_init(k2, (m, 2 * h)) * 0.1,
        "wo": _dense_init(k3, (din, m)),
        "norm": jnp.zeros((m,)),
    }
    s = {"wqkv": ("embed", "ffn"), "wif": ("embed", None),
         "wo": ("ffn", "embed"), "norm": (None,)}
    return p, s


def apply_mlstm(cfg: ModelConfig, p: Params, x: jax.Array,
                cache: Optional[Params] = None):
    """mLSTM: matrix-memory LSTM.  C_t = f_t C + i_t v k^T ; h = C q / max(|n.q|,1).
    Maps onto the chunked SSM kernel (state = C, plus a 1-row state for n)."""
    b, l, m = x.shape
    h, dh = cfg.n_heads, cfg.head_dim_
    din = h * dh
    xn = rms_norm(x, p["norm"])
    qkv = linear(cfg, p["wqkv"], xn)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = linear(cfg, p["wif"], xn).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                            # (B,L,H)
    i = jax.nn.sigmoid(ig)
    f = jax.nn.sigmoid(fg + 3.0)  # bias toward remembering

    qh = q.reshape(b, l, h, dh) * dh ** -0.5
    kh = k.reshape(b, l, h, dh) * dh ** -0.5
    vh = v.reshape(b, l, h, dh)

    def flat(t):  # (B,L,H,D) -> (B*H, L, D) — compute dtype; the chunked
        # scan accumulates in f32 (§Perf zamba2 iteration 4 applies here too)
        return jnp.moveaxis(t, 2, 1).reshape(b * h, l, -1).astype(compute_dtype(cfg))

    xf = flat(vh * i[..., None].astype(vh.dtype))
    af = jnp.moveaxis(f, 2, 1).reshape(b * h, l)
    bf, cf = flat(kh), flat(qh)

    if cache is None or l > 1:
        y = ops.ssm(xf, af, bf, cf, cfg.use_kernels)                 # (BH,L,D)
        iflat = jnp.moveaxis(i, 2, 1).reshape(b * h, l)
        ones = jnp.ones((b * h, l, 1), jnp.float32) * iflat[..., None]
        nsum = ops.ssm(ones, af, bf, cf, cfg.use_kernels)            # (BH,L,1)
        new_cache = None
        if cache is not None:  # prefill: final (C, n) state
            log_a = jnp.log(jnp.maximum(af, 1e-37))                  # (BH,L)
            cum = jnp.cumsum(log_a, axis=1)
            w = jnp.exp(cum[:, -1:] - cum)                           # (BH,L)
            C_fin = jnp.einsum("zl,zlp,zln->zpn", w, xf, bf,
                               preferred_element_type=jnp.float32)
            n_fin = jnp.einsum("zl,zl,zln->zn", w, iflat, bf,
                               preferred_element_type=jnp.float32)[:, None]
            new_cache = {"C": C_fin, "n": n_fin}
    else:
        C_prev, n_prev = cache["C"], cache["n"]                      # (BH,D,N),(BH,1,N)
        a1 = af[:, 0][:, None, None]
        C_new = a1 * C_prev + jnp.einsum("zp,zn->zpn", xf[:, 0], bf[:, 0])
        n_new = a1 * n_prev + jnp.einsum("z,zn->zn", jnp.moveaxis(i, 2, 1)
                                         .reshape(b * h, l)[:, 0], bf[:, 0])[:, None]
        y = jnp.einsum("zpn,zn->zp", C_new, cf[:, 0])[:, None]
        nsum = jnp.einsum("zqn,zn->zq", n_new, cf[:, 0])[:, None]
        new_cache = {"C": C_new, "n": n_new}

    hout = y / jnp.maximum(jnp.abs(nsum), 1.0)
    hout = jnp.moveaxis(hout.reshape(b, h, l, dh), 1, 2).reshape(b, l, din)
    return x + linear(cfg, p["wo"], hout.astype(x.dtype)), new_cache


def init_slstm(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    m = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wx": _dense_init(k1, (m, 4 * m)),
        "wr": _dense_init(k2, (m, 4 * m)) * 0.5,
        "bias": jnp.zeros((4 * m,)),
        "wo": _dense_init(k3, (m, m)),
        "norm": jnp.zeros((m,)),
    }
    s = {"wx": ("embed", "ffn"), "wr": ("embed", "ffn"), "bias": (None,),
         "wo": ("embed", "embed"), "norm": (None,)}
    return p, s


def apply_slstm(cfg: ModelConfig, p: Params, x: jax.Array,
                cache: Optional[Params] = None):
    """sLSTM with stabilized exponential gating (sequential scan — the
    inherently-recurrent xLSTM component)."""
    b, l, m = x.shape
    xn = rms_norm(x, p["norm"])
    xproj = (linear(cfg, p["wx"], xn) + p["bias"].astype(xn.dtype)).astype(jnp.float32)

    if cache is None:
        h0 = jnp.zeros((b, m), jnp.float32)
        state0 = (h0, h0, h0, h0 - 10.0)  # h, c, n, mstab
    else:
        state0 = (cache["h"], cache["c"], cache["n"], cache["m"])

    wr = p["wr"].astype(jnp.float32)

    def step(state, xt):
        hprev, cprev, nprev, mprev = state
        pre = xt + hprev @ wr
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        mnew = jnp.maximum(ft + mprev, it)
        i = jnp.exp(it - mnew)
        f = jnp.exp(ft + mprev - mnew)
        c = f * cprev + i * z
        n = f * nprev + i
        hnew = o * c / jnp.maximum(n, 1.0)
        return (hnew, c, n, mnew), hnew

    statef, hs = jax.lax.scan(step, state0, jnp.moveaxis(xproj, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                      # (B,L,M)
    new_cache = None
    if cache is not None:
        new_cache = {"h": statef[0], "c": statef[1], "n": statef[2], "m": statef[3]}
    return x + linear(cfg, p["wo"], hs), new_cache

"""Model configuration for all assigned architectures.

One `ModelConfig` describes any member of the supported families:
dense / moe / ssm (xLSTM) / hybrid (Mamba2+shared-attn) / vlm / audio (enc-dec).
`src/repro/configs/<arch>.py` instantiates these with the published numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention pattern
    attn_pattern: str = "full"     # full | sliding | local_global
    window: int = 0                # sliding/local window length
    local_global_ratio: int = 0    # gemma3: 5 local : 1 global

    # mixture of experts
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # "einsum": GShard one-hot dispatch/combine (reference; O(S·cap) ⇒
    #           quadratic in seq).  "index": gather/scatter dispatch with the
    #           SAME capacity-drop rule — no dispatch matmuls (§Perf MoE).
    moe_dispatch: str = "einsum"

    # state-space / recurrent
    ssm_state: int = 0             # N (mamba2 state dim)
    ssm_headdim: int = 64          # P
    ssm_expand: int = 2
    conv_width: int = 4
    hybrid_attn_every: int = 0     # zamba2: shared attn block every k layers
    slstm_ratio: int = 0           # xlstm: 1 sLSTM per k blocks (k=2 -> alternate)

    # encoder-decoder (seamless)
    encoder_layers: int = 0

    # embeddings / frontends
    frontend: str = "none"         # none | vision | audio (stub embeddings)
    mrope: bool = False            # qwen2-vl M-RoPE (3 position streams)
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # numerics / execution
    dtype: str = "bfloat16"
    use_photonic_mac: bool = False  # route linears through the photonic-MAC QAT op
    photonic_bits: int = 8
    # int8 weight "wire format" (§Perf): ZeRO-3 param all-gathers cross the
    # mesh at the MR weight-bank amplitude resolution (8-bit), dequantized
    # after the wire.  Only active under fsdp_all (actx gates it); 0 = off.
    wire_bits: int = 0
    use_kernels: bool = False       # Pallas kernels (False -> XLA reference path)
    remat: str = "full"             # none | full | dots
    loss_chunk: int = 1024          # CE computed in seq chunks (no full-logit materialization)

    # parallelism hints (logical->mesh rules read these)
    fsdp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") for the largest archs
    scan_layers: bool = True
    # "tp_fsdp"  : Megatron TP over `model` + FSDP over fsdp_axes (baseline)
    # "fsdp_all" : ZeRO-3 over the WHOLE mesh, no tensor parallelism
    # "seq_tp"   : FSDP + sequence-sharded attention (context parallel) with
    #              TP MLP — for archs whose head count won't divide `model`
    parallel_strategy: str = "tp_fsdp"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale version of the same family (CPU-runnable)."""
        small_layers = {
            "local_global": max(2, self.local_global_ratio + 1),
        }.get(self.attn_pattern, 0)
        if self.hybrid_attn_every:
            small_layers = self.hybrid_attn_every + 1
        if self.slstm_ratio:
            small_layers = 2 * self.slstm_ratio
        n_layers = max(2, small_layers)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(self.n_heads, 1)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            window=min(self.window, 32) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            encoder_layers=2 if self.encoder_layers else 0,
            loss_chunk=64,
            dtype="float32",
        )

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        m, f, v = self.d_model, self.d_ff, self.vocab
        h, hk, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        attn = m * dh * (h + 2 * hk) + h * dh * m
        mlp = 3 * m * f
        if self.n_experts:
            mlp = self.n_experts * 3 * m * f + m * self.n_experts
        per_layer = attn + mlp
        if self.family == "ssm":
            din = self.d_inner
            mlstm = m * (2 * din + 2 * self.ssm_state * self.ssm_heads) + din * m
            per_layer = mlstm  # coarse
        if self.family == "hybrid":
            din = self.d_inner
            per_layer = m * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * m
        total = self.n_layers * per_layer + v * m * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 2 * m * f)
        return float(total)

    def active_param_count(self) -> float:
        if not self.n_experts:
            return self.param_count()
        dense_share = self.param_count() - self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        return dense_share + self.n_layers * self.top_k * 3 * self.d_model * self.d_ff

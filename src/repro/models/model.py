"""Unified LM covering every assigned architecture family.

An architecture is a sequence of *stages*; each stage is `(repeat, kinds)` —
`kinds` is a tuple of block kinds executed in order, and the stage is scanned
`repeat` times with per-kind parameters stacked along a leading "layers" axis
(`jax.lax.scan` keeps the HLO small: 512-device SPMD lowering of a 95-layer
model compiles in seconds).

Block kinds:
  attn         dense attention (+MLP); window per cfg.attn_pattern
  local/global gemma3 5:1 interleave (sliding window vs full)
  moe          attention + top-k routed experts
  mamba        Mamba2 selective-SSM block (zamba2)
  shared_attn  zamba2's weight-shared attention block (one param set, many
               invocations, per-invocation KV caches)
  mlstm/slstm  xLSTM blocks
  enc / dec    encoder (bidirectional) / decoder (causal + cross-attn)

Entry points: `init`, `loss_fn` (train), `prefill` + `serve_step` (inference),
`encode` (enc-dec).  All return/accept explicit pytrees; logical-axis spec
trees mirror the params for the sharding rules in `repro.parallel`.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel import actx
from repro.parallel import wire as W

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# stage layout
# ---------------------------------------------------------------------------


def stages(cfg: ModelConfig) -> List[Tuple[int, Tuple[str, ...]]]:
    nl = cfg.n_layers
    if cfg.family in ("dense", "vlm"):
        return [(nl, ("attn",))]
    if cfg.family == "moe":
        return [(nl, ("moe",))]
    if cfg.attn_pattern == "local_global" and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        group = ("local",) * r + ("global",)
        full, rem = divmod(nl, r + 1)
        out = [(full, group)]
        if rem:
            out.append((1, ("local",) * rem))
        return out
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        group = ("mamba",) * (e - 1) + ("shared_attn",)
        full, rem = divmod(nl, e)
        out = [(full, group)]
        if rem:
            out.append((1, ("mamba",) * rem))
        return out
    if cfg.family == "ssm" and cfg.slstm_ratio:
        r = cfg.slstm_ratio
        group = ("mlstm",) * (r - 1) + ("slstm",)
        full, rem = divmod(nl, r)
        out = [(full, group)]
        if rem:
            out.append((1, ("mlstm",) * rem))
        return out
    if cfg.family == "audio":
        return [(nl, ("dec",))]
    raise ValueError(f"cannot derive stages for {cfg.name}")


_ATTN_KINDS = ("attn", "local", "global", "moe", "shared_attn", "enc", "dec")


def _kind_window(cfg: ModelConfig, kind: str) -> int:
    if kind == "local":
        return cfg.window
    if kind == "global":
        return 0
    if kind in ("attn", "moe"):
        return cfg.window if cfg.attn_pattern == "sliding" else 0
    if kind == "shared_attn":
        # TPU adaptation (DESIGN.md): hybrid shared-attn uses sliding window at
        # long context; window=0 within normal contexts
        return cfg.window
    return 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    if kind in ("attn", "local", "global", "enc"):
        pa, sa = L.init_attention(cfg, ks[0])
        pm, sm = L.init_mlp(cfg, ks[1]) if cfg.d_ff else ({}, {})
        return {"attn": pa, **({"mlp": pm} if pm else {})}, \
               {"attn": sa, **({"mlp": sm} if sm else {})}
    if kind == "moe":
        pa, sa = L.init_attention(cfg, ks[0])
        pe, se = L.init_moe(cfg, ks[1])
        return {"attn": pa, "moe": pe}, {"attn": sa, "moe": se}
    if kind == "mamba":
        return (lambda r: ({"mamba": r[0]}, {"mamba": r[1]}))(L.init_mamba(cfg, ks[0]))
    if kind == "shared_attn":
        return {}, {}  # params live in the shared slot
    if kind == "mlstm":
        return (lambda r: ({"mlstm": r[0]}, {"mlstm": r[1]}))(L.init_mlstm(cfg, ks[0]))
    if kind == "slstm":
        return (lambda r: ({"slstm": r[0]}, {"slstm": r[1]}))(L.init_slstm(cfg, ks[0]))
    if kind == "dec":
        pa, sa = L.init_attention(cfg, ks[0])
        px, sx = L.init_cross_attention(cfg, ks[1])
        pm, sm = L.init_mlp(cfg, ks[2])
        return {"attn": pa, "cross": px, "mlp": pm}, \
               {"attn": sa, "cross": sx, "mlp": sm}
    raise ValueError(kind)


def _stack_init(cfg: ModelConfig, kind: str, key, repeat: int):
    keys = jax.random.split(key, repeat)
    _, spec = _init_block(cfg, kind, keys[0])
    stacked = jax.vmap(lambda k: _init_block(cfg, kind, k)[0])(keys)
    spec = jax.tree.map(lambda ax: ("layers",) + tuple(ax), spec,
                        is_leaf=lambda x: isinstance(x, tuple))
    return stacked, spec


def init(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    keys = jax.random.split(key, 8 + len(stages(cfg)))
    p: Params = {}
    s: Params = {}
    emb_scale = cfg.d_model ** -0.5
    p["embed"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * emb_scale
    s["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(keys[1], (cfg.d_model, cfg.vocab)) * emb_scale
        s["lm_head"] = ("embed", "vocab")
    p["final_norm"] = jnp.zeros((cfg.d_model,))
    s["final_norm"] = (None,)

    p["stages"], s["stages"] = [], []
    for i, (repeat, kinds) in enumerate(stages(cfg)):
        sp, ss = {}, {}
        for j, kind in enumerate(kinds):
            name = f"{kind}_{j}"
            sp[name], ss[name] = _stack_init(cfg, kind, jax.random.fold_in(keys[2 + i], j), repeat)
        p["stages"].append(sp)
        s["stages"].append(ss)

    if any("shared_attn" in kinds for _, kinds in stages(cfg)):
        pa, sa = L.init_attention(cfg, keys[6])
        p["shared_attn"], s["shared_attn"] = pa, sa

    if cfg.encoder_layers:
        enc_p, enc_s = _stack_init(cfg, "enc", keys[7], cfg.encoder_layers)
        p["encoder"] = {"blocks": enc_p, "norm": jnp.zeros((cfg.d_model,))}
        s["encoder"] = {"blocks": enc_s, "norm": (None,)}
    return p, s


def init_abstract(cfg: ModelConfig):
    """(params as ShapeDtypeStructs, logical-axis specs) with NO allocation —
    the dry-run path for 314B-parameter configs on a CPU container."""
    box = {}

    def f(key):
        p, s = init(cfg, key)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["s"]


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _kind_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    dt = L.compute_dtype(cfg)
    hk, dh = cfg.n_kv_heads, cfg.head_dim_
    if kind in _ATTN_KINDS:
        w = _kind_window(cfg, kind)
        length = min(w, cache_len) if w else cache_len
        z = jnp.zeros((batch, hk, length, dh), dt)
        return {"k": z, "v": z}, {"k": ("batch", "kv_heads", "cache", "head_dim"),
                                  "v": ("batch", "kv_heads", "cache", "head_dim")}
    if kind == "mamba":
        hm, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        return (
            {"state": jnp.zeros((batch, hm, pdim, n), jnp.float32),
             "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dt)},
            {"state": ("batch", None, None, None),
             "conv": ("batch", None, "ffn")},
        )
    if kind == "mlstm":
        h, dh_ = cfg.n_heads, cfg.head_dim_
        return (
            {"C": jnp.zeros((batch * h, dh_, dh_), jnp.float32),
             "n": jnp.zeros((batch * h, 1, dh_), jnp.float32)},
            {"C": ("batch", None, None), "n": ("batch", None, None)},
        )
    if kind == "slstm":
        m = cfg.d_model
        z = jnp.zeros((batch, m), jnp.float32)
        sp = ("batch", None)
        return {"h": z, "c": z, "n": z, "m": z - 10.0}, \
               {"h": sp, "c": sp, "n": sp, "m": sp}
    raise ValueError(kind)


def init_cache_abstract(cfg: ModelConfig, batch: int, cache_len: int):
    """(cache ShapeDtypeStructs, specs) without allocation."""
    box = {}

    def f():
        c, s = init_cache(cfg, batch, cache_len)
        box["s"] = s
        return c

    shapes = jax.eval_shape(f)
    return shapes, box["s"]


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    cache, spec = [], []
    for repeat, kinds in stages(cfg):
        cs, ss = {}, {}
        for j, kind in enumerate(kinds):
            c1, s1 = _kind_cache(cfg, kind, batch, cache_len)
            cs[f"{kind}_{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (repeat,) + x.shape), c1)
            ss[f"{kind}_{j}"] = jax.tree.map(
                lambda ax: ("layers",) + tuple(ax), s1,
                is_leaf=lambda x: isinstance(x, tuple))
        cache.append(cs)
        spec.append(ss)
    return cache, spec


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, kind: str, p: Params, x, positions, *,
                 shared: Optional[Params], cache, cache_pos, enc_out):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "global"):
        w = _kind_window(cfg, kind)
        x, nc = L.apply_attention(cfg, p["attn"], x, positions, window=w,
                                  cache=cache and _sub(cache), cache_pos=cache_pos)
        if "mlp" in p:
            x = L.apply_mlp(cfg, p["mlp"], x)
        return x, nc, aux
    if kind == "moe":
        w = _kind_window(cfg, kind)
        x, nc = L.apply_attention(cfg, p["attn"], x, positions, window=w,
                                  cache=cache and _sub(cache), cache_pos=cache_pos)
        x, aux = L.apply_moe(cfg, p["moe"], x)
        return x, nc, aux
    if kind == "shared_attn":
        w = _kind_window(cfg, kind)
        x, nc = L.apply_attention(cfg, shared, x, positions, window=w,
                                  cache=cache and _sub(cache), cache_pos=cache_pos)
        return x, nc, aux
    if kind == "mamba":
        x, nc = L.apply_mamba(cfg, p["mamba"], x, cache=cache)
        return x, nc, aux
    if kind == "mlstm":
        x, nc = L.apply_mlstm(cfg, p["mlstm"], x, cache=cache)
        return x, nc, aux
    if kind == "slstm":
        x, nc = L.apply_slstm(cfg, p["slstm"], x, cache=cache)
        return x, nc, aux
    if kind == "enc":
        x, nc = L.apply_attention(cfg, p["attn"], x, positions, causal=False)
        x = L.apply_mlp(cfg, p["mlp"], x)
        return x, nc, aux
    if kind == "dec":
        x, nc = L.apply_attention(cfg, p["attn"], x, positions,
                                  cache=cache and _sub(cache), cache_pos=cache_pos)
        x = L.apply_cross_attention(cfg, p["cross"], x, enc_out, positions)
        x = L.apply_mlp(cfg, p["mlp"], x)
        return x, nc, aux
    raise ValueError(kind)


def _sub(cache):
    return {"k": cache["k"], "v": cache["v"]} if cache and "k" in cache else cache


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat == "dots_all":
        # save EVERY dot output (attention einsums included): no matmul is
        # ever recomputed in backward — §Perf deepseek iteration 4 (trades
        # activation memory for the last ~10% of recompute)
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _run_stages(cfg: ModelConfig, params: Params, x, positions, *,
                cache=None, cache_pos=None, enc_out=None):
    """Scan every stage.  Returns (x, new_cache, aux_total)."""
    shared = params.get("shared_attn")
    new_cache_all = [] if cache is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    for si, (repeat, kinds) in enumerate(stages(cfg)):
        sp = params["stages"][si]
        scache = cache[si] if cache is not None else None

        def body(carry, xs, _kinds=kinds):
            xc, auxc = carry
            xc = actx.constrain_batch(xc)
            layer_p, layer_c = xs
            # int8 wire pairs (repro.parallel.wire) dequantize at body entry,
            # so the per-layer ZeRO-3 all-gather moves the 1-byte payload
            layer_p = W.dequant_subtree(layer_p, L.compute_dtype(cfg))
            new_c = {}
            for j, kind in enumerate(_kinds):
                name = f"{kind}_{j}"
                c_j = layer_c.get(name) if layer_c is not None else None
                xc, nc, aux = _apply_block(
                    cfg, kind, layer_p.get(name, {}), xc, positions,
                    shared=shared, cache=c_j, cache_pos=cache_pos,
                    enc_out=enc_out)
                if nc is not None:
                    new_c[name] = nc
            return (xc, auxc + aux), new_c

        body = _remat(cfg, body)
        xs = (sp, scache)
        if cache is None:
            xs = (sp, None)
            (x, aux_total), _ = jax.lax.scan(
                lambda c, lp: body(c, (lp, None)), (x, aux_total), sp)
        else:
            (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total), xs)
            new_cache_all.append(ncs)
    return x, new_cache_all, aux_total


# ---------------------------------------------------------------------------
# embedding / heads
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array):
    dt = L.compute_dtype(cfg)
    return actx.constrain_batch(params["embed"].astype(dt)[tokens])


def logits_head(cfg: ModelConfig, params: Params, h: jax.Array):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return L.linear(cfg, w, h).astype(jnp.float32)


def default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0):
    """offset: scalar, or (B,) vector (continuous batching — per-slot
    positions)."""
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 1:
        off = off[:, None]
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + off
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


# ---------------------------------------------------------------------------
# encoder (seamless)
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: Params, enc_embeds: jax.Array):
    """enc_embeds: precomputed audio-frontend frames (B, Se, M) — the modality
    frontend is a stub per the assignment."""
    b, se, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))
    x = enc_embeds.astype(L.compute_dtype(cfg))
    ep = params["encoder"]["blocks"]

    def body(carry, lp):
        xc, aux = carry
        xc = actx.constrain_batch(xc)
        lp = W.dequant_subtree(lp, L.compute_dtype(cfg))
        xc, _, a = _apply_block(cfg, "enc", lp["enc_0"], xc, positions,
                                shared=None, cache=None, cache_pos=None,
                                enc_out=None)
        return (xc, aux + a), None

    (x, _), _ = jax.lax.scan(_remat(cfg, body), (x, jnp.zeros((), jnp.float32)),
                             {"enc_0": ep} if "enc_0" not in ep else ep)
    return L.rms_norm(x, params["encoder"]["norm"])


# ---------------------------------------------------------------------------
# train / serve entry points
# ---------------------------------------------------------------------------


def forward_hidden(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    """Full-sequence forward.  Returns (hidden, aux)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision" and "pixel_embeds" in batch:
        npix = batch["pixel_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["pixel_embeds"].astype(x.dtype), x[:, npix:]], axis=1)
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(cfg, params, batch["enc_embeds"])
    x, _, aux = _run_stages(cfg, params, x, positions, enc_out=enc_out)
    return L.rms_norm(x, params["final_norm"]), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    """Chunked cross-entropy: logits are materialized one sequence-chunk at a
    time (under remat) so the (B,S,V) tensor never exists."""
    h, aux = forward_hidden(cfg, params, batch)
    h = actx.constrain_batch(h)
    labels = batch["labels"]
    b, s, m = h.shape
    chunk = min(cfg.loss_chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    hc = jnp.moveaxis(h.reshape(b, n_chunks, chunk, m), 1, 0)
    yc = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)

    @_remat_ce(cfg)
    def chunk_ce(hx, yx):
        logits = actx.constrain(logits_head(cfg, params, hx),
                                ("dp", None, "tp"))    # (B, chunk, V) f32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yx[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    total = jnp.sum(jax.lax.map(lambda args: chunk_ce(*args), (hc, yc)))
    ntok = b * s
    loss = total / ntok + 1e-2 * aux
    return loss, {"ce": total / ntok, "aux": aux}


def _remat_ce(cfg):
    def deco(fn):
        return jax.checkpoint(fn) if cfg.remat != "none" else fn
    return deco


def train_logits(cfg: ModelConfig, params: Params, batch):
    """Small-scale helper (tests/examples): full logits."""
    h, _ = forward_hidden(cfg, params, batch)
    return logits_head(cfg, params, h)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            cache_len: int | None = None):
    """Run the full prompt, return (last_logits, cache).  `cache_len` sizes
    the KV/state cache (>= prompt length; default prompt + 1 so at least one
    decode step fits)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    enc_out = encode(cfg, params, batch["enc_embeds"]) if cfg.encoder_layers else None

    cache, _ = init_cache(cfg, b, cache_len or (s + 1))
    x, new_cache, _ = _run_stages(cfg, params, x, positions,
                                  cache=cache, cache_pos=jnp.int32(0),
                                  enc_out=enc_out)
    h = L.rms_norm(x[:, -1:], params["final_norm"])
    return logits_head(cfg, params, h), new_cache


def serve_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
               pos: jax.Array, enc_out: Optional[jax.Array] = None):
    """One decode step: tokens (B,1) at absolute position `pos` (scalar).
    Returns (logits (B,1,V), new_cache)."""
    b = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    positions = default_positions(cfg, b, 1, offset=pos)
    x, new_cache, _ = _run_stages(cfg, params, x, positions,
                                  cache=cache, cache_pos=pos, enc_out=enc_out)
    h = L.rms_norm(x, params["final_norm"])
    return logits_head(cfg, params, h), new_cache

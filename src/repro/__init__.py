"""repro: silicon-photonic 2.5D interposer networks (TRINE + 2.5D-CrossLight)
reproduced as (A) an analytical photonic model and (B) a TPU-scale JAX
training/serving framework embodying the paper's communication insights."""
__version__ = "1.0.0"

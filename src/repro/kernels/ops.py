"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy (DESIGN.md §7):
  * TPU      -> compiled Pallas kernels (the target).
  * CPU      -> `interpret=True` (kernel body executed in Python/XLA-CPU) for
                correctness tests, or the pure-jnp reference for speed.
  * dry-run  -> reference path (`use_kernels=False` in model configs), so
                `cost_analysis()` sees the FLOPs/bytes (Pallas custom-calls
                are opaque to HLO cost analysis).

Training: kernel-forward / oracle-backward via custom_vjp — the Pallas
kernels here are forward-only; backward runs the jnp reference's VJP (same
math, XLA-fused).  `photonic_matmul` adds a straight-through estimator so the
quantized (MR-bank) forward trains with full-precision master weights.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_fwd
from repro.kernels.photonic_mac import (
    photonic_mac as _mac_fwd,
    quantize_weights,
    DEFAULT_BK,
    DEFAULT_BN,
)
from repro.kernels.ssm_scan import ssm_scan as _ssm_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# photonic matmul with straight-through quantization
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def photonic_matmul(x: jax.Array, w: jax.Array, bits: int = 8,
                    use_kernel: bool = True) -> jax.Array:
    """out = x @ quantize(w): forward through the photonic-MAC numerics
    (per-tile int quantization), backward straight-through to full-precision
    w (standard QAT; the photonic weight banks are programmed from the master
    weights at deploy time)."""
    return _photonic_fwd_impl(x, w, bits, use_kernel)


def _photonic_fwd_impl(x, w, bits, use_kernel):
    k, n = w.shape
    if k % DEFAULT_BK or n % DEFAULT_BN or x.shape[0] % 128:
        # shape not tileable -> reference numerics (same quantization math)
        w_q, scale = _tile_quantize_any(w, bits)
        return jnp.dot(x.astype(jnp.float32), w_q,
                       precision=jax.lax.Precision.HIGHEST)
    w_q, scale = quantize_weights(w, bits=bits)
    if use_kernel:
        return _mac_fwd(x, w_q, scale, interpret=_on_cpu())
    return _ref.photonic_mac_ref(x, w_q, scale)


def _tile_quantize_any(w, bits):
    """Whole-matrix fallback quantization (per-column scale) for non-tileable
    shapes; returns dequantized weights + scale."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / qmax
    w_q = jnp.clip(jnp.round(w / scale[None, :]), -qmax, qmax) * scale[None, :]
    return w_q.astype(jnp.float32), scale


def _photonic_vjp_fwd(x, w, bits, use_kernel):
    out = _photonic_fwd_impl(x, w, bits, use_kernel)
    return out, (x, w)


def _photonic_vjp_bwd(bits, use_kernel, res, g):
    x, w = res
    g = g.astype(jnp.float32)
    # straight-through: gradient flows as if w were unquantized
    dx = jnp.dot(g, w.T.astype(jnp.float32)).astype(x.dtype)
    dw = jnp.dot(x.T.astype(jnp.float32), g).astype(w.dtype)
    return dx, dw


photonic_matmul.defvjp(_photonic_vjp_fwd, _photonic_vjp_bwd)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def attention(q, k, v, causal: bool = True, window: int = 0,
              scale: float | None = None, q_offset: int = 0,
              use_kernel: bool = True):
    """Flash attention (kernel fwd) with oracle VJP. Shapes (B,H*,S,D)."""
    return _attention_impl(q, k, v, causal, window, scale, q_offset, use_kernel)


def _attention_impl(q, k, v, causal, window, scale, q_offset, use_kernel):
    sq, sk = q.shape[2], k.shape[2]
    tileable = (
        use_kernel
        and sq % min(128, sq) == 0
        and sk % min(128, sk) == 0
        and q_offset % min(128, sq) == 0
        and sk >= 8 and sq >= 8
    )
    if tileable:
        return _flash_fwd(q, k, v, causal=causal, window=window, scale=scale,
                          q_offset=q_offset, interpret=_on_cpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)


def _attention_vjp_fwd(q, k, v, causal, window, scale, q_offset, use_kernel):
    out = _attention_impl(q, k, v, causal, window, scale, q_offset, use_kernel)
    return out, (q, k, v)


def _attention_vjp_bwd(causal, window, scale, q_offset, use_kernel, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.attention_ref(
            q_, k_, v_, causal=causal, window=window, scale=scale,
            q_offset=q_offset),
        q, k, v)
    return vjp(g)


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def ssm(x, a, b, c, use_kernel: bool = True):
    """Chunked selective scan (kernel fwd, oracle VJP).
    x (BH,L,P), a (BH,L), b/c (BH,L,N)."""
    return _ssm_impl(x, a, b, c, use_kernel)


def _ssm_impl(x, a, b, c, use_kernel):
    l = x.shape[1]
    if use_kernel and l % min(128, l) == 0 and l >= 8:
        return _ssm_fwd(x, a, b, c, interpret=_on_cpu())
    # XLA fallback = the same chunked SSD algorithm (L/chunk trips, MXU-shaped
    # dots), NOT the sequential oracle — §Perf zamba2 iteration 2
    return _ref.ssm_scan_chunked_ref(x, a, b, c)


def _ssm_vjp_fwd(x, a, b, c, use_kernel):
    return _ssm_impl(x, a, b, c, use_kernel), (x, a, b, c)


def _ssm_vjp_bwd(use_kernel, res, g):
    x, a, b, c = res
    _, vjp = jax.vjp(_ref.ssm_scan_chunked_ref, x, a, b, c)
    return vjp(g)


ssm.defvjp(_ssm_vjp_fwd, _ssm_vjp_bwd)

"""Photonic MAC kernel — 2.5D-CrossLight's broadcast-and-weight numerics on TPU.

The paper's photonic MAC units (Sec. V) imprint weights onto per-wavelength
optical amplitudes with MR filters (limited amplitude resolution — the MR
tuning DAC gives 4..8 bits), multiply noncoherently, and sum partial products
in balanced photodetectors (analog, effectively full-precision accumulation).

TPU adaptation (DESIGN.md §3): a blocked matmul whose weights are
**integer-quantized per (bk × bn) tile with a per-tile scale** — each tile is
one "MR weight bank" whose dynamic range is set by its own tuning — while
activations stay bf16 and accumulation runs in f32 on the MXU (the
photodetector analog-sum analog).  Wavelength-parallelism (#WDM λ) maps to the
K-dimension tile width.

Layout:
  x        (M, K)   bf16/f32 activations
  w_q      (K, N)   int8 quantized weights
  w_scale  (K/bk, N/bn) f32 per-tile scales
  out      (M, N)   f32

Grid (M/bm, N/bn, K/bk); K is the sequential (arbitrary) dimension with an
f32 VMEM accumulator. Tile defaults are MXU-aligned (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _mac_kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    # dequantize this weight-bank tile: int levels * per-tile scale
    w = wq_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def photonic_mac(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Quantized-weight matmul: out = x @ (w_q * per-tile scale).

    Shapes need not be tile-aligned: non-multiples (vocab tails, odd hidden
    dims) are zero-padded up to the (bm, bn, bk) grid and the result sliced
    back — padded activation columns multiply padded zero weight rows, so
    the f32 accumulator sees exact +0 contributions and aligned shapes are
    bit-identical to the unpadded kernel.  `w_scale` is per weight-bank tile
    on the ceil grid: shape (ceil(k/bk), ceil(n/bn)).
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    n_i = pl.cdiv(m, bm)
    n_j = pl.cdiv(n, bn)
    n_k = pl.cdiv(k, bk)
    assert w_scale.shape == (n_k, n_j), (w_scale.shape, (n_k, n_j))

    mp, kp, np_ = n_i * bm, n_k * bk, n_j * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        functools.partial(_mac_kernel, n_k=n_k),
        grid=(n_i, n_j, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, w_scale)
    return out if (mp, np_) == (m, n) else out[:m, :n]


def quantize_weights(
    w: jax.Array, bits: int = 8, bk: int = DEFAULT_BK, bn: int = DEFAULT_BN
):
    """Per-(bk x bn)-tile symmetric quantization — one scale per MR weight
    bank, range set by the bank's own max |w| (the MR tuning range).

    Non-tile-aligned weights quantize on the zero-padded ceil grid (padding
    is exact zero, so it never widens a bank's absmax range; all-padding
    tiles fall back to the epsilon scale) and `w_q` is sliced back to (k, n).
    `w_scale` comes back (ceil(k/bk), ceil(n/bn)) — exactly what
    `photonic_mac` expects for the same (bk, bn)."""
    k, n = w.shape
    kp, np_ = -(-k // bk) * bk, -(-n // bn) * bn
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    tiles = w.reshape(kp // bk, bk, np_ // bn, bn)
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(tiles), axis=(1, 3))  # (ceil(k/bk), ceil(n/bn))
    scale = jnp.maximum(absmax, 1e-8) / qmax
    w_q = jnp.clip(
        jnp.round(tiles / scale[:, None, :, None]), -qmax, qmax
    ).astype(jnp.int8)
    return w_q.reshape(kp, np_)[:k, :n], scale.astype(jnp.float32)

"""Photonic MAC kernel — 2.5D-CrossLight's broadcast-and-weight numerics on TPU.

The paper's photonic MAC units (Sec. V) imprint weights onto per-wavelength
optical amplitudes with MR filters (limited amplitude resolution — the MR
tuning DAC gives 4..8 bits), multiply noncoherently, and sum partial products
in balanced photodetectors (analog, effectively full-precision accumulation).

TPU adaptation (DESIGN.md §3): a blocked matmul whose weights are
**integer-quantized per (bk × bn) tile with a per-tile scale** — each tile is
one "MR weight bank" whose dynamic range is set by its own tuning — while
activations stay bf16 and accumulation runs in f32 on the MXU (the
photodetector analog-sum analog).  Wavelength-parallelism (#WDM λ) maps to the
K-dimension tile width.

Layout:
  x        (M, K)   bf16/f32 activations
  w_q      (K, N)   int8 quantized weights
  w_scale  (K/bk, N/bn) f32 per-tile scales
  out      (M, N)   f32

Grid (M/bm, N/bn, K/bk); K is the sequential (arbitrary) dimension with an
f32 VMEM accumulator. Tile defaults are MXU-aligned (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _mac_kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    # dequantize this weight-bank tile: int levels * per-tile scale
    w = wq_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def photonic_mac(
    x: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Quantized-weight matmul: out = x @ (w_q * per-tile scale)."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) must tile by ({bm},{bn},{bk})"
    )
    assert w_scale.shape == (k // bk, n // bn), w_scale.shape
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_mac_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, w_scale)


def quantize_weights(
    w: jax.Array, bits: int = 8, bk: int = DEFAULT_BK, bn: int = DEFAULT_BN
):
    """Per-(bk x bn)-tile symmetric quantization — one scale per MR weight
    bank, range set by the bank's own max |w| (the MR tuning range)."""
    k, n = w.shape
    assert k % bk == 0 and n % bn == 0, (w.shape, bk, bn)
    tiles = w.reshape(k // bk, bk, n // bn, bn)
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(tiles), axis=(1, 3))  # (k/bk, n/bn)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    w_q = jnp.clip(
        jnp.round(tiles / scale[:, None, :, None]), -qmax, qmax
    ).astype(jnp.int8)
    return w_q.reshape(k, n), scale.astype(jnp.float32)

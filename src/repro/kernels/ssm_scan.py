"""Chunked selective-state-space scan kernel (Mamba2/SSD-style) for the
zamba2 hybrid and xLSTM mLSTM blocks.

Recurrence (per batch*head, matrix state S in R^{P x N}):
    S_t = a_t * S_{t-1} + x_t ⊗ b_t          (a_t scalar decay per step)
    y_t = S_t c_t

Chunked closed form (chunk length C, cum_t = prod_{s<=t} a_s within chunk):
    y_t   = cum_t * (S_in c_t) + sum_{s<=t} (cum_t/cum_s) (b_s·c_t) x_s
    S_out = cum_C * S_in + sum_s (cum_C/cum_s) x_s ⊗ b_s

Grid (B*H, n_chunks) with the chunk dimension sequential; S carried in VMEM
scratch.  The intra-chunk term is two MXU matmuls ((M⊙G)ᵀX and the gram
B Cᵀ) — this is the standard SSD chunking, mapped to TPU tiles.

Shapes: x (BH, L, P), a (BH, L), b (BH, L, N), c (BH, L, N) -> y (BH, L, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssm_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)            # (C, P)
    a = a_ref[0].astype(jnp.float32)            # (C,)
    b = b_ref[0].astype(jnp.float32)            # (C, N)
    c = c_ref[0].astype(jnp.float32)            # (C, N)

    log_a = jnp.log(jnp.maximum(a, 1e-37))
    cum = jnp.exp(jnp.cumsum(log_a))            # (C,) inclusive cumprod
    s_in = s_ref[...]                           # (P, N)

    # carry-in contribution: y_carry[t] = cum_t * (c_t @ S_in^T)
    y_carry = cum[:, None] * jax.lax.dot_general(
        c, s_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (C, P)

    # intra-chunk: decay matrix M[s,t] = cum_t / cum_s for s <= t
    ratio = cum[None, :] / jnp.maximum(cum[:, None], 1e-37)
    st_mask = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        <= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    m = jnp.where(st_mask, ratio, 0.0)          # (C, C), rows=s, cols=t
    g = jax.lax.dot_general(b, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C_s, C_t)
    w = (m * g)                                 # (s, t)
    y_intra = jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (t, P)

    y_ref[0] = (y_carry + y_intra).astype(y_ref.dtype)

    # state update: S_out = cum_C S_in + sum_s (cum_C / cum_s) x_s b_s^T
    wgt = cum[-1] / jnp.maximum(cum, 1e-37)     # (C,)
    s_ref[...] = cum[-1] * s_in + jax.lax.dot_general(
        x * wgt[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(
    x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
    *, chunk: int = DEFAULT_CHUNK, interpret: bool = False,
) -> jax.Array:
    bh, l, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, l)
    assert l % ch == 0, (l, ch)
    assert a.shape == (bh, l) and b.shape == (bh, l, n) and c.shape == (bh, l, n)

    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=ch),
        grid=(bh, l // ch),
        in_specs=[
            pl.BlockSpec((1, ch, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ch), lambda i, j: (i, j)),
            pl.BlockSpec((1, ch, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ch, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, ch, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)

"""Flash-attention forward kernel (Pallas TPU) with GQA and sliding-window
support — the memory-bound compute hot-spot of every assigned LM architecture.

Online-softmax over KV blocks: grid (batch*q_heads, q_blocks, kv_blocks) with
kv as the sequential dimension; running (m, l, acc) in VMEM scratch.  GQA is
handled in the BlockSpec index map (q head h reads kv head h // group) — no
materialized KV repetition.  Sliding-window / causal masks are applied from
program ids, and fully-masked KV blocks are skipped by the index map never
being reached (we rely on masking; block skipping is a TPU-side optimization
recorded in EXPERIMENTS.md §Perf).

Shapes (already head-split):
  q (B, Hq, Sq, D) ; k, v (B, Hk, Sk, D) ; out (B, Hq, Sq, D) f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BKV = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, bq: int, bkv: int,
    n_kv: int, q_offset_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)

    # absolute positions: q rows may be offset (decode: queries at the end)
    q_pos = (qi + q_offset_blocks) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # rows with no valid key yet: keep l/acc at 0 (p underflows to 0 via NEG_INF)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bkv", "q_offset", "interpret"),
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int = 0, scale: float | None = None,
    bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV, q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q (B,Hq,Sq,D); k,v (B,Hk,Sk,D); GQA when Hq > Hk. q_offset: absolute
    position of q[...,0,:] (for decode with a prefilled KV cache)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    assert hq % hk == 0, (hq, hk)
    group = hq // hk
    bq_ = min(bq, sq)
    bkv_ = min(bkv, sk)
    assert sq % bq_ == 0 and sk % bkv_ == 0, (sq, sk, bq_, bkv_)
    assert q_offset % bq_ == 0, "q_offset must be a multiple of the q block"
    scale = scale if scale is not None else d ** -0.5
    n_kv = sk // bkv_

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hk, sk, d)
    vr = v.reshape(b * hk, sk, d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: query head -> kv head
        bidx = bh // hq
        h = bh % hq
        return (bidx * hk + h // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, window=window,
            bq=bq_, bkv=bkv_, n_kv=n_kv, q_offset_blocks=q_offset // bq_,
        ),
        grid=(b * hq, sq // bq_, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq_, d), q_map),
            pl.BlockSpec((1, bkv_, d), kv_map),
            pl.BlockSpec((1, bkv_, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)

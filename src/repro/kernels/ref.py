"""Pure-jnp oracles for every Pallas kernel.  Tests assert_allclose the
kernels (interpret mode on CPU) against these; ops.py also uses their VJPs
for the backward pass (kernel-forward / oracle-backward pattern)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def photonic_mac_ref(x, w_q, w_scale, bk: int = 128, bn: int = 128):
    """Dequantize-then-matmul oracle. w_q (K,N) int8, w_scale on the ceil
    tile grid (ceil(K/bk), ceil(N/bn)) — non-aligned shapes use the scale
    grid's leading (K, N) window, mirroring the kernel's zero-pad+slice."""
    w = dequantize_ref(w_q, w_scale, bk, bn)
    return jnp.dot(x.astype(jnp.float32), w, precision=jax.lax.Precision.HIGHEST)


def dequantize_ref(w_q, w_scale, bk: int = 128, bn: int = 128):
    k, n = w_q.shape
    scale_full = jnp.repeat(jnp.repeat(w_scale, bk, axis=0), bn, axis=1)
    return w_q.astype(jnp.float32) * scale_full[:k, :n]


def attention_ref(q, k, v, *, causal=True, window=0, scale=None, q_offset=0):
    """Naive softmax attention with GQA + causal/sliding-window masks.
    q (B,Hq,Sq,D); k,v (B,Hk,Sk,D)."""
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    group = hq // hk
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def ssm_scan_chunked_ref(x, a, b, c, chunk: int = 128):
    """Chunked (SSD block-decomposition) scan — the same math the Pallas
    kernel implements, in pure jnp.  This is the production XLA fallback and
    the dry-run path: trips drop L -> L/chunk and the per-step rank-1 updates
    become MXU-shaped matmuls (ch x ch x {N,P}).  Validated against the
    sequential oracle `ssm_scan_ref` (test_kernels.py).

    x (BH,L,P), a (BH,L), b/c (BH,L,N) -> y (BH,L,P)."""
    bh, l, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, l)
    if l % ch:  # non-tileable tail -> sequential oracle
        return ssm_scan_ref(x, a, b, c)
    nc = l // ch
    # decay math stays f32 (log/cumsum/exp); the big einsum operands run in
    # the input dtype (bf16 from the model -> half the HBM traffic) with f32
    # MXU accumulation — §Perf zamba2 iteration 3.
    dt = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    f32 = jnp.float32
    xf = x.reshape(bh, nc, ch, p).astype(dt)
    af = a.reshape(bh, nc, ch).astype(f32)
    bf = b.reshape(bh, nc, ch, n).astype(dt)
    cf = c.reshape(bh, nc, ch, n).astype(dt)

    log_a = jnp.log(jnp.maximum(af, 1e-37))
    cum_log = jnp.cumsum(log_a, axis=-1)                    # (bh,nc,ch)

    # intra-chunk: decay(s,t) = exp(cum_t - cum_s) for s <= t (log-space segsum)
    dlog = cum_log[..., None, :] - cum_log[..., :, None]    # (bh,nc,s,t)
    mask = jnp.arange(ch)[:, None] <= jnp.arange(ch)[None, :]
    m = jnp.where(mask, jnp.exp(jnp.clip(dlog, -80.0, 0.0)), 0.0)
    g = jnp.einsum("zksn,zktn->zkst", bf, cf,
                   preferred_element_type=f32)              # gram B C^T
    y_intra = jnp.einsum("zkst,zksp->zktp", (m * g).astype(dt), xf,
                         preferred_element_type=f32)

    # per-chunk state contribution and decay
    cum = jnp.exp(cum_log)
    wgt = jnp.exp(jnp.clip(cum_log[..., -1:] - cum_log, -80.0, 0.0))
    s_chunk = jnp.einsum("zksp,zksn->zkpn", xf * wgt[..., None].astype(dt), bf,
                         preferred_element_type=f32)
    a_chunk = cum[..., -1]                                  # (bh,nc)

    # inter-chunk scan (nc trips): carry-in state per chunk (f32 carry)
    def step(s, inp):
        s_c, a_c = inp
        return a_c[:, None, None] * s + s_c, s
    s0 = jnp.zeros((bh, p, n), f32)
    _, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                         # (bh,nc,p,n)

    y_carry = jnp.einsum("zktn,zkpn->zktp", (cf.astype(f32) * cum[..., None]).astype(dt),
                         s_in.astype(dt), preferred_element_type=f32)
    return (y_carry + y_intra).reshape(bh, l, p)


def ssm_scan_ref(x, a, b, c):
    """Naive sequential scan oracle.  x (BH,L,P), a (BH,L), b/c (BH,L,N)."""
    bh, l, p = x.shape
    n = b.shape[-1]

    def step(s, inp):
        xt, at, bt, ct = inp
        s = at[:, None, None] * s + jnp.einsum("zp,zn->zpn", xt, bt)
        y = jnp.einsum("zpn,zn->zp", s, ct)
        return s, y

    s0 = jnp.zeros((bh, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(b, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)  # (BH, L, P)

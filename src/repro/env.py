"""Environment flags shared by benchmarks, examples, and tests.

Two knobs are recognized:

  REPRO_SMOKE     truthy -> tiny-grid / few-step CI smoke runs.
  REPRO_PREFETCH  integer >= 0 -> streaming-pipeline prefetch depth for the
                  chunked sweep/search engine (how many chunks may be in
                  flight on the device ahead of the reducer fold).  0 means
                  fully serial (enqueue, block, fold); the default of 2 keeps
                  one chunk computing while the previous one folds —
                  double-buffering.  Any depth produces bit-identical reducer
                  states; the knob only trades memory for overlap.
"""

from __future__ import annotations

import os

_TRUTHY = ("1", "true", "yes", "on")

DEFAULT_PREFETCH = 2


def smoke_mode(default: bool = False) -> bool:
    """True when REPRO_SMOKE requests tiny-grid / few-step CI smoke runs.

    The single source of truth for the flag's accepted values — benchmarks
    and examples must not re-parse the variable themselves, so the contract
    cannot silently diverge between entry points.
    """
    raw = os.environ.get("REPRO_SMOKE")
    if raw is None:
        return default
    return raw.strip().lower() in _TRUTHY


def prefetch_depth(default: int = DEFAULT_PREFETCH) -> int:
    """Streaming-pipeline prefetch depth from REPRO_PREFETCH (clamped >= 0).

    Single source of truth for the flag, mirroring `smoke_mode`: the engine
    (`core.sweep.sweep_chunked` and everything layered on it) consults this
    when no explicit ``prefetch=`` argument is given.  Unparseable values
    fall back to the default rather than erroring — a misconfigured shell
    must not change results, only scheduling.
    """
    raw = os.environ.get("REPRO_PREFETCH")
    if raw is None:
        return default
    try:
        return max(0, int(raw.strip()))
    except ValueError:
        return default

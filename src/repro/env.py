"""Environment flags shared by benchmarks, examples, and tests."""

from __future__ import annotations

import os

_TRUTHY = ("1", "true", "yes", "on")


def smoke_mode(default: bool = False) -> bool:
    """True when REPRO_SMOKE requests tiny-grid / few-step CI smoke runs.

    The single source of truth for the flag's accepted values — benchmarks
    and examples must not re-parse the variable themselves, so the contract
    cannot silently diverge between entry points.
    """
    raw = os.environ.get("REPRO_SMOKE")
    if raw is None:
        return default
    return raw.strip().lower() in _TRUTHY

"""xlstm-350m — sLSTM + mLSTM blocks (attention-free) [arXiv:2405.04517;
unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab=50304,
    slstm_ratio=4,  # 3 mLSTM : 1 sLSTM per group
    tie_embeddings=True,
)

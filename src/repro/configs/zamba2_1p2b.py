"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks
[arXiv:2411.15242; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, conv_width=4,
    hybrid_attn_every=6,
    window=4096,  # shared-attn blocks go sliding-window at long context
)

"""qwen2-vl-72b — VLM backbone with M-RoPE; vision frontend stubbed
(precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    frontend="vision", mrope=True,
    rope_theta=1e6,
    fsdp_axes=("pod", "data"),
)

"""seamless-m4t-medium — enc-dec, audio frontend stubbed (precomputed frame
embeddings) [arXiv:2308.11596; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=256206,
    encoder_layers=12, frontend="audio",
    rope_theta=1e4,
)

"""Architecture registry: one module per assigned architecture.

`get(arch_id)` -> ModelConfig (full published config)
`get_reduced(arch_id)` -> CPU-smoke-scale config of the same family
`SHAPES` -> the four assigned input-shape cells
`input_specs(cfg, shape)` -> ShapeDtypeStruct stand-ins for every model input
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCH_IDS = [
    "deepseek_67b",
    "yi_6b",
    "gemma3_27b",
    "yi_34b",
    "grok1_314b",
    "mixtral_8x7b",
    "xlstm_350m",
    "qwen2_vl_72b",
    "zamba2_1p2b",
    "seamless_m4t_medium",
]

# assignment-normalized aliases (--arch deepseek-67b etc.)
ALIASES = {a.replace("_", "-").replace("-1p2b", "-1.2b"): a for a in ARCH_IDS}
ALIASES.update({a: a for a in ARCH_IDS})
ALIASES["grok-1-314b"] = "grok1_314b"   # assignment spelling


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long_decode"),
}


def get(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return get(arch).reduced()


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if supported, else the skip reason (recorded in EXPERIMENTS.md)."""
    if shape.kind == "long_decode":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.attn_pattern in ("sliding", "local_global")
        )
        if not sub_quadratic:
            return ("pure full-attention arch: long_500k requires "
                    "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every input of the lowered step
    (weak-type-correct, shardable, no device allocation)."""
    from repro.models import model as M

    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    if shape.kind == "train":
        batch = {"tokens": tok(b, s), "labels": tok(b, s)}
        if cfg.mrope:
            batch["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        if cfg.frontend == "vision":
            batch["pixel_embeds"] = jax.ShapeDtypeStruct(
                (b, 256, cfg.d_model), jnp.bfloat16)
        if cfg.encoder_layers:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, max(1, s // 4), cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": tok(b, s)}
        if cfg.mrope:
            batch["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        if cfg.encoder_layers:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, max(1, s // 4), cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    # decode / long_decode: one new token against a cache of length s
    cache, _ = M.init_cache_abstract(cfg, b, s)
    spec = {
        "cache": cache,
        "tokens": tok(b, 1),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.encoder_layers:
        spec["enc_out"] = jax.ShapeDtypeStruct(
            (b, max(1, s // 4), cfg.d_model), jnp.bfloat16)
    return spec

"""deepseek-67b — dense llama-arch, GQA [arXiv:2401.02954; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=102400,
    rope_theta=1e4,
    fsdp_axes=("pod", "data"),  # 67B fp32 master+adam: shard over both axes
)

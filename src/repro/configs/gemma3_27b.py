"""gemma3-27b — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    attn_pattern="local_global", local_global_ratio=5, window=1024,
    rope_theta=1e6, tie_embeddings=True,
    fsdp_axes=("pod", "data"),
)

"""AdamW built from scratch (no optax), with distributed-optimization tricks:

  * optimizer-state compression: m/v stored in bf16 (configurable) — halves
    sharded optimizer memory (needed to fit grok-1-314b on a 256-chip pod),
  * global-norm clipping computed in f32 regardless of state dtype,
  * cosine schedule with linear warmup,
  * state sharded identically to params (FSDP "memory-chiplet" layout).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # "bfloat16" = optimizer-state compression


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    m: Any
    v: Any


def init_state(cfg: OptConfig, params) -> TrainState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def state_specs(param_specs):
    """Logical-axis spec tree for a TrainState built over `param_specs`."""
    return TrainState(step=None, params=param_specs,
                      m=param_specs, v=param_specs)


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, state: TrainState, grads) -> TrainState:
    step = state.step + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, state.params, grads, state.m, state.v)
    params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return TrainState(step=step, params=params, m=m, v=v)

"""Layer-A (analytical photonic model) tests: paper-stated facts, physical
invariants (hypothesis), and the Fig. 4 / Fig. 6 validation checks."""

import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CNN_WORKLOADS, DEFAULT_DEVICES, NetworkParams, Traffic,
    choose_subnetworks, crosslight_25d_elec, crosslight_25d_siph,
    evaluate_accelerator, evaluate_network, laser_electrical_power_w,
    monolithic_crosslight, plan_collective_channels, plan_gateway_activation,
    spacx_bus, sprint_bus, tree_network, trine_network,
)


# ---------------------------------------------------------------------------
# paper-stated facts (Sec. IV)
# ---------------------------------------------------------------------------

def test_paper_subnetwork_count():
    """'With a modulation frequency of 12 GHz and a gateway frequency of
    2 GHz, we opted for 8 subnetworks' — 100GB/s memory, 8-lambda waveguides."""
    assert choose_subnetworks(NetworkParams()) == 8


def test_paper_stage_counts():
    """'The use of 8 subnetworks and 32 gateways results in 2 switch stages
    for TRINE, contrasting with 5 stages in the Tree network topology.'"""
    p = NetworkParams()
    assert trine_network(p).n_stages == 2
    assert tree_network(p).n_stages == 5


def test_tree_bandwidth_limited_to_one_waveguide():
    p = NetworkParams()
    assert tree_network(p).aggregate_bw_bps == p.n_lambda * p.modulation_rate_bps


def test_trine_bandwidth_matches_memory():
    p = NetworkParams()
    net = trine_network(p)
    mem_bits = p.n_mem_chiplets * p.mem_bw_bytes_per_s * 8
    assert net.aggregate_bw_bps <= mem_bits  # never over-provisioned
    assert net.aggregate_bw_bps >= 0.9 * mem_bits  # but matched


def test_trine_loss_below_alternatives():
    p = NetworkParams()
    trine = trine_network(p)
    for other in (sprint_bus(p), spacx_bus(p), tree_network(p)):
        assert trine.worst_path_loss_db < other.worst_path_loss_db


# ---------------------------------------------------------------------------
# physical invariants (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(g1=st.integers(min_value=8, max_value=64))
def test_bus_loss_monotone_in_gateways(g1):
    """More writers/readers on a bus waveguide => strictly more loss — the
    paper's core argument against bus topologies."""
    p1 = NetworkParams(n_gateways=g1)
    p2 = NetworkParams(n_gateways=g1 + 8)
    assert sprint_bus(p2).worst_path_loss_db > sprint_bus(p1).worst_path_loss_db


@settings(max_examples=30, deadline=None)
@given(loss=st.floats(min_value=0.0, max_value=30.0),
       extra=st.floats(min_value=0.1, max_value=10.0))
def test_laser_power_exponential_in_loss(loss, extra):
    """Laser power compounds exponentially with dB loss (linear units)."""
    p1 = float(laser_electrical_power_w(loss, 8, n_banks=1))
    p2 = float(laser_electrical_power_w(loss + extra, 8, n_banks=1))
    fixed = DEFAULT_DEVICES.laser.bank_overhead_w
    assert (p2 - fixed) / (p1 - fixed) == pytest.approx(10 ** (extra / 10), rel=1e-6)


@settings(max_examples=20, deadline=None)
@given(mem_gbps=st.integers(min_value=10, max_value=400))
def test_subnetworks_scale_with_memory_bw(mem_gbps):
    p = NetworkParams(mem_bw_bytes_per_s=mem_gbps * 1e9, n_gateways=256)
    k = choose_subnetworks(p)
    wg = p.n_lambda * p.modulation_rate_bps
    # K covers the memory bandwidth within its power-of-two rounding (the
    # paper itself rounds 9 -> 8), and the next halving would not
    assert k * wg >= 0.5 * mem_gbps * 8e9
    assert (k & (k - 1)) == 0  # power of two, balanced trees


@settings(max_examples=30, deadline=None)
@given(demand=st.floats(min_value=0, max_value=2e11),
       maxbw=st.floats(min_value=1e9, max_value=1e11),
       n=st.integers(min_value=1, max_value=64))
def test_gateway_activation_bounds(demand, maxbw, n):
    f = plan_gateway_activation(demand, maxbw, n)
    assert 0 < f <= 1.0
    # activation covers demand (up to full saturation)
    if demand < maxbw:
        assert f * maxbw >= min(demand, maxbw) - maxbw / n


@settings(max_examples=30, deadline=None)
@given(nbytes=st.floats(min_value=1, max_value=1e10),
       window=st.floats(min_value=1e-6, max_value=1.0))
def test_collective_channels_monotone(nbytes, window):
    c1 = plan_collective_channels(nbytes, window, 50e9)
    c2 = plan_collective_channels(nbytes * 2, window, 50e9)
    assert 1 <= c1 <= 8 and c1 <= c2 <= 8


# ---------------------------------------------------------------------------
# network evaluation sanity + figure checks
# ---------------------------------------------------------------------------

def test_network_eval_positive_and_consistent():
    p = NetworkParams()
    t = Traffic(bytes_read=1e8, bytes_written=5e7, n_transfers=100)
    for net in (sprint_bus(p), spacx_bus(p), tree_network(p), trine_network(p)):
        r = evaluate_network(net, t)
        assert r.latency_s > 0 and r.energy_j > 0 and r.power_w > 0
        assert r.energy_per_bit_j == pytest.approx(
            r.energy_j / t.total_bits, rel=1e-9)


def test_pcmc_activation_saves_energy():
    """2.5D-CrossLight claim: deactivating gateways on low-traffic layers
    saves laser power/energy."""
    p = NetworkParams()
    net = trine_network(p)
    t = Traffic(bytes_read=1e6, bytes_written=1e5, n_transfers=10)
    full = evaluate_network(net, t, active_fraction=1.0)
    half = evaluate_network(net, t, active_fraction=0.5)
    assert half.laser_power_w < full.laser_power_w


def test_fig4_checks_pass():
    import benchmarks.fig4_trine as f4
    out = f4.run(csv=False)
    assert all(out["checks"].values()), out["checks"]


def test_fig6_checks_pass():
    import benchmarks.fig6_crosslight as f6
    out = f6.run(csv=False)
    assert all(out["checks"].values()), (out["checks"], out["avg"])


def test_fig6_lenet_exception():
    """Paper: 2.5D platform is inefficient for LeNet5 — monolithic is
    competitive there, and only there."""
    mono = monolithic_crosslight()
    siph = crosslight_25d_siph()
    lenet = CNN_WORKLOADS["LeNet5"]()
    vgg = CNN_WORKLOADS["VGG16"]()
    assert (evaluate_accelerator(mono, lenet).latency_s
            < 2.5 * evaluate_accelerator(siph, lenet).latency_s)
    assert (evaluate_accelerator(mono, vgg).latency_s
            > 5 * evaluate_accelerator(siph, vgg).latency_s)

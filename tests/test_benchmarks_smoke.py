"""Benchmark / example entrypoint smoke tests: every benchmark `run()` and
example script executes end-to-end in a tiny-grid smoke mode, so regressions
in the benchmark/example layer break tier-1 instead of rotting silently.
(The seed repo was red at import time for exactly this class of rot.)

Benchmarks run in-process (they are analytical and fast).  Examples run as
subprocesses with REPRO_SMOKE=1 and the smallest argument sets their CLIs
accept — except serve_batched, whose reduced-model serve still compiles for
minutes on this CPU container; its driver (repro.launch.serve / serve.engine)
is exercised by tests/test_serving.py, so here it only gets a compile check.
"""

import os
import py_compile
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
EXAMPLES = REPO / "examples"


# ---------------------------------------------------------------------------
# benchmarks (in-process)
# ---------------------------------------------------------------------------


def test_fig4_benchmark_smoke():
    import benchmarks.fig4_trine as b
    out = b.run(csv=False)
    assert len(out["rows"]) == 6 * 4
    assert all(out["checks"].values()), out["checks"]


def test_fig6_benchmark_smoke():
    import benchmarks.fig6_crosslight as b
    out = b.run(csv=False)
    assert len(out["rows"]) == 6
    assert all(out["checks"].values()), out["checks"]


def test_sweep_bench_smoke():
    import benchmarks.sweep_bench as b
    out = b.run(csv=False, smoke=True)
    assert out["checks"]["batched_matches_scalar"], out
    assert out["checks"]["speedup_over_bar"], out
    assert out["n_configs"] >= 128


def test_roofline_benchmark_smoke():
    import benchmarks.roofline as b
    out = b.run(csv=False)
    assert len(out["photonic"]) == 6 * 3
    # the paper's qualitative Sec. V story: the SiPh interposer is never
    # slower than the electrical mesh on the network term
    by = {(r["accel"], r["cnn"]): r for r in out["photonic"]}
    for name in ("ResNet18", "VGG16"):
        assert (by[("2.5D-CrossLight-SiPh", name)]["network_s"]
                <= by[("2.5D-CrossLight-Elec", name)]["network_s"])
    assert b.photonic_markdown_table(out["photonic"]).count("|") > 20


def test_collectives_benchmark_smoke():
    import benchmarks.collectives_bench as b
    out = b.run(csv=False)
    assert out


def test_photonic_mac_benchmark_smoke():
    import benchmarks.photonic_mac_bench as b
    out = b.run(csv=False)
    assert out


# ---------------------------------------------------------------------------
# examples (subprocess, REPRO_SMOKE=1 + smallest CLI args)
# ---------------------------------------------------------------------------


def _run_example(script: str, *args: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    env["REPRO_SMOKE"] = "1"
    r = subprocess.run(
        [sys.executable, str(EXAMPLES / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO))
    assert r.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{r.stdout[-2000:]}"
        f"\n--- stderr ---\n{r.stderr[-2000:]}")
    return r.stdout


def test_example_photonic_design_space():
    out = _run_example("photonic_design_space.py")
    assert "EDP-optimal K = 8" in out
    assert "EDP-optimal" in out.split("Full design-space search")[1]


def test_example_quickstart():
    out = _run_example("quickstart.py")
    assert "TRINE" in out


def test_example_train_e2e():
    out = _run_example("train_e2e.py", "--steps", "2")
    assert "final_step" in out or "loss" in out


def test_example_continuous_batching():
    out = _run_example("continuous_batching.py", "--requests", "2",
                       "--slots", "2", "--max-len", "64")
    assert "req" in out


def test_example_photonic_mac_ablation():
    out = _run_example("photonic_mac_ablation.py")
    assert "photonic 8-bit" in out


def test_example_serve_batched_compiles():
    # full run compiles a reduced LM serve path for minutes on CPU; the
    # driver itself is covered by tests/test_serving.py
    py_compile.compile(str(EXAMPLES / "serve_batched.py"), doraise=True)

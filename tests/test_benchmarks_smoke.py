"""Benchmark / example entrypoint smoke tests: every benchmark `run()` and
example script executes end-to-end in a tiny-grid smoke mode, so regressions
in the benchmark/example layer break tier-1 instead of rotting silently.
(The seed repo was red at import time for exactly this class of rot.)

Benchmarks run in-process (they are analytical and fast).  Examples run as
subprocesses with REPRO_SMOKE=1 and the smallest argument sets their CLIs
accept — including serve_batched, whose smoke path (reduced model, batch 2,
16-token prompts, 4 new tokens) now executes the real prefill + decode loop
in ~15s on this CPU container.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
EXAMPLES = REPO / "examples"


# ---------------------------------------------------------------------------
# benchmarks (in-process)
# ---------------------------------------------------------------------------


def test_fig4_benchmark_smoke():
    import benchmarks.fig4_trine as b
    out = b.run(csv=False)
    assert len(out["rows"]) == 6 * 4
    assert all(out["checks"].values()), out["checks"]


def test_fig6_benchmark_smoke():
    import benchmarks.fig6_crosslight as b
    out = b.run(csv=False)
    assert len(out["rows"]) == 6
    assert all(out["checks"].values()), out["checks"]


def test_sweep_bench_smoke():
    import benchmarks.sweep_bench as b
    out = b.run(csv=False, smoke=True)
    assert out["checks"]["batched_matches_scalar"], out
    assert out["checks"]["speedup_over_bar"], out
    assert out["n_configs"] >= 128
    # smoke reporting is honest: the grid-size check reflects the grid that
    # actually ran (a 192-point smoke grid is NOT >= 4096) and smoke mode
    # only exempts it via required_checks, with the smoke flag recorded
    assert out["smoke"] is True
    assert out["checks"]["grid_at_least_4096"] == (out["n_configs"] >= 4096)
    assert "grid_at_least_4096" not in out["required_checks"]
    assert out["pass"], out


@pytest.fixture(scope="module")
def pareto_out():
    """One smoke pareto/co-design bench run shared by the tests below (it
    now spans the first-order AND trust-region refinements, so run it
    once)."""
    import benchmarks.pareto_bench as b
    return b.run(csv=False, smoke=True)


def test_pareto_bench_smoke(pareto_out):
    """Pareto/co-design bench: fronts exact, perf-regression gates hold
    (chunked within the smoke ratio bar of monolithic, batched over scalar
    over the smoke bar)."""
    out = pareto_out
    assert out["checks"]["net_front_streaming_equals_monolithic"]
    assert out["checks"]["net_front_matches_bruteforce"]
    assert out["checks"]["codesign_front_streaming_equals_monolithic"]
    assert out["checks"]["codesign_front_matches_bruteforce"]
    assert out["checks"]["chunked_within_ratio_bar_network"], out["network"]
    assert out["checks"]["chunked_within_ratio_bar_codesign"], out["codesign"]
    assert out["checks"]["batched_over_scalar_bar"], out["network"]
    assert out["checks"]["refinement_improves"]
    assert out["pass"], out
    # smoke honesty: joint grid size reported as-run, 1e6 check exempted
    # (not rewritten) in smoke mode
    assert out["smoke"] is True
    assert out["codesign"]["n_joint_points"] < 1_000_000
    assert not out["checks"]["codesign_grid_at_least_1e6"]
    assert "codesign_grid_at_least_1e6" not in out["required_checks"]


def test_pareto_bench_trust_region_gates(pareto_out):
    """The trust-region multi-workload section: its merged front weakly
    dominates the first-order refined front, every refined design re-scores
    bit-identically, and both gates are REQUIRED even in smoke mode (no
    exemption — the contracts are exact, not throughput-dependent)."""
    out = pareto_out
    assert out["checks"]["trust_region_front_dominates_first_order"]
    assert out["checks"]["trust_region_rescore_bit_identical"]
    assert "trust_region_front_dominates_first_order" in out["required_checks"]
    assert "trust_region_rescore_bit_identical" in out["required_checks"]
    tr = out["trust_region_front"]
    assert len(tr["workloads"]) == 3  # joint refinement, not single-workload
    assert tr["trust_region_front_size"] >= 1
    assert tr["seeds_refined"] >= 1
    ls = tr["line_search"]
    assert ls and all(s["value"] <= s["snap_value"] for s in ls)


@pytest.fixture(scope="module")
def fabric_whatif_out():
    """One smoke what-if run shared by the tests below (it spans a Pareto
    search + fabric pricing, so run it once)."""
    import benchmarks.fabric_whatif as b
    return b.run(csv=False, smoke=True)


def test_fabric_whatif_benchmark_smoke(fabric_whatif_out):
    """The search->system loop: >= 3 fabrics (metallic baseline + photonic
    presets + co-design frontier points), per-(arch x shape) roofline terms
    under each, and at least one bottleneck flip vs metallic involving a
    frontier fabric."""
    import benchmarks.fabric_whatif as b
    out = fabric_whatif_out
    assert out["pass"], out["checks"]
    assert len(out["fabrics"]) >= 3
    assert any(f["kind"] == "frontier" for f in out["fabrics"])
    # every cell is priced under every fabric
    assert len(out["results"]) == len(out["cells"]) * len(out["fabrics"])
    # fabric-ranked frontier is a subset of the fabrics that came from the
    # EDP front (no invented design points)
    frontier_names = {f["name"] for f in out["fabrics"]
                      if f["kind"] == "frontier"}
    assert set(out["frontier_ranking"]) == frontier_names
    assert (b.ARTIFACTS / "fabric_whatif.json").exists()


def test_roofline_fabric_columns():
    """Measured dry-run cells re-priced per fabric: the metallic row must
    reproduce the cell's own roofline terms, the photonic rows move the
    collective term with the link bandwidth."""
    import benchmarks.roofline as b
    cell = {"arch": "yi_6b", "shape": "decode_32k", "mesh": "single",
            "status": "ok", "collective_op_counts": {"all-reduce": 65},
            "roofline": {"flops": 3.0e9, "hbm_bytes": 5.6e8,
                         "collective_bytes": 9.8e6, "model_flops": 3.0e9}}
    rows = b.fabric_cells([cell])
    assert [r["fabric"] for r in rows] == list(b.FABRIC_NAMES)
    by = {r["fabric"]: r for r in rows}
    assert by["trine_siph"]["collective_s"] < by["metallic_ici"]["collective_s"]
    assert by["tree_siph"]["collective_s"] > by["metallic_ici"]["collective_s"]
    # the 12 GB/s tree link flips this memory-bound decode cell
    assert by["metallic_ici"]["bottleneck"] == "memory"
    assert by["tree_siph"]["bottleneck"] == "collective"
    assert b.fabric_markdown_table(rows).count("|") > 20


def test_run_summary_consolidation(fabric_whatif_out, pareto_out):
    """benchmarks.run consolidates per-bench checks + perf gates into one
    summary (the artifacts/summary.json payload)."""
    import benchmarks.run as runner
    import benchmarks.sweep_bench as sb
    results = {"sweep": sb.run(csv=False, smoke=True),
               "pareto": pareto_out,
               "fabric_whatif": fabric_whatif_out}
    summary = runner.build_summary(results)
    assert summary["pass"], summary["checks"]
    # the trust-region gates are folded in as required in both modes, and
    # the refinement-trajectory block records both engines
    assert summary["checks"]["pareto/trust_region_front_dominates_first_order"]
    assert summary["checks"]["pareto/trust_region_rescore_bit_identical"]
    ref = summary["refinement"]
    assert ref["trust_region_dominates_first_order"] is True
    assert ref["trust_region"]["best_improvement"] is not None
    assert ref["first_order"]["merged_front_size"] >= 1
    assert summary["perf"]["batched_over_scalar"]["pass"]
    assert summary["perf"]["chunked_over_monolithic_network"]["pass"]
    assert summary["perf"]["chunked_over_monolithic_codesign"]["pass"]
    # fabric what-if gates: artifact schema + the frontier bottleneck flip
    assert summary["checks"]["fabric_whatif/schema_keys"]
    assert summary["checks"]["fabric_whatif/schema_result_rows"]
    assert summary["checks"]["fabric_whatif/schema_has_frontier"]
    assert summary["checks"]["fabric_whatif/bottleneck_flip_frontier_fabric"]
    # smoke-exempt checks must not leak into the consolidated gate
    assert "pareto/codesign_grid_at_least_1e6" not in summary["checks"]
    assert "sweep/grid_at_least_4096" not in summary["checks"]


def test_roofline_benchmark_smoke():
    import benchmarks.roofline as b
    out = b.run(csv=False)
    assert len(out["photonic"]) == 6 * 3
    # the paper's qualitative Sec. V story: the SiPh interposer is never
    # slower than the electrical mesh on the network term
    by = {(r["accel"], r["cnn"]): r for r in out["photonic"]}
    for name in ("ResNet18", "VGG16"):
        assert (by[("2.5D-CrossLight-SiPh", name)]["network_s"]
                <= by[("2.5D-CrossLight-Elec", name)]["network_s"])
    assert b.photonic_markdown_table(out["photonic"]).count("|") > 20


def test_resilience_benchmark_smoke():
    """Survivability bench: monotone degradation curves, replanning never
    loses to the naive schedule, TRINE's bank redundancy beats the
    single-bank tree, and the Monte-Carlo availability column streams over
    a >= 1e5-point grid even in smoke (chunking bounds memory, not grid
    size — so there is no smoke exemption: every check is required)."""
    import benchmarks.resilience_bench as b
    out = b.run(csv=False, smoke=True)
    assert out["checks"]["monotone_degradation"]
    assert out["checks"]["replan_recovers"], out["recovery"]
    assert out["checks"]["trine_redundancy_beats_tree"], out["availability"]
    assert out["checks"]["availability_grid_at_least_1e5"]
    assert out["yield_grid"]["n_points"] >= 100_000
    assert out["checks"]["expected_edp_ge_healthy"]
    assert out["required_checks"] == list(out["checks"])
    assert out["pass"], out["checks"]
    assert (b.ARTIFACTS / "resilience.json").exists()


def test_report_creates_and_updates_experiments(tmp_path):
    """benchmarks.report: regenerating into a missing file seeds it with
    the header + generated-tables marker instead of crashing on the
    FileNotFoundError (the fresh-checkout regression), a second run is
    idempotent, and hand-written prose above the marker survives."""
    import benchmarks.report as report
    target = tmp_path / "EXPERIMENTS.md"
    report.main(path=target)
    text = target.read_text()
    assert text.startswith("# EXPERIMENTS")
    assert report.MARK in text
    report.main(path=target)
    assert target.read_text() == text  # idempotent
    target.write_text("# my notes\n\ncustom prose\n\n" + report.MARK + "\n")
    report.main(path=target)
    out = target.read_text()
    assert out.startswith("# my notes")
    assert "custom prose" in out and report.MARK in out
    # the real module-level target exists in this checkout (the repo ships
    # a seeded EXPERIMENTS.md so `python -m benchmarks.report` always works)
    assert report.EXPERIMENTS.exists()


def test_collectives_benchmark_smoke():
    import benchmarks.collectives_bench as b
    out = b.run(csv=False)
    assert out


def test_photonic_mac_benchmark_smoke():
    import benchmarks.photonic_mac_bench as b
    out = b.run(csv=False)
    assert out


# ---------------------------------------------------------------------------
# examples (subprocess, REPRO_SMOKE=1 + smallest CLI args)
# ---------------------------------------------------------------------------


def _run_example(script: str, *args: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    env["REPRO_SMOKE"] = "1"
    r = subprocess.run(
        [sys.executable, str(EXAMPLES / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=str(REPO))
    assert r.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{r.stdout[-2000:]}"
        f"\n--- stderr ---\n{r.stderr[-2000:]}")
    return r.stdout


def test_example_photonic_design_space():
    out = _run_example("photonic_design_space.py")
    assert "EDP-optimal K = 8" in out
    assert "EDP-optimal" in out.split("Full design-space search")[1]


def test_example_quickstart():
    out = _run_example("quickstart.py")
    assert "TRINE" in out


def test_example_train_e2e():
    out = _run_example("train_e2e.py", "--steps", "2")
    assert "final_step" in out or "loss" in out


def test_example_continuous_batching():
    out = _run_example("continuous_batching.py", "--requests", "2",
                       "--slots", "2", "--max-len", "64")
    assert "req" in out


def test_example_photonic_mac_ablation():
    out = _run_example("photonic_mac_ablation.py")
    assert "photonic 8-bit" in out


def test_example_serve_batched():
    """Real smoke run of the serve path (prefill + greedy decode with KV
    cache): REPRO_SMOKE shrinks the example to batch 2 / 16-token prompts /
    4 new tokens on the reduced model, which finishes in ~15s here — so
    tier-1 executes the serving loop instead of compile-checking it (the
    old ROADMAP caveat)."""
    out = _run_example("serve_batched.py")
    assert "prefill:" in out and "decode" in out
    assert "generated shape: (2, 4)" in out

"""Deterministic fallback for the `hypothesis` API surface this suite uses.

The container image does not ship hypothesis and nothing may be pip-installed,
so `tests/conftest.py` registers this module under ``sys.modules["hypothesis"]``
when the real package is absent.  It covers exactly the strategies the tests
draw from (integers / floats / sampled_from) and replays each ``@given`` test
over a fixed, seeded sample set — property tests become deterministic
parametrized sweeps instead of silently vanishing.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 20
_MAX_EXAMPLES_CAP = 50  # keep the fallback sweeps fast


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def example(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     boundaries=(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     boundaries=(min_value, max_value))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: rng.choice(seq), boundaries=(seq[0], seq[-1]))


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**named_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = min(getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            names = sorted(named_strategies)
            # first example pins every strategy at its lower boundary, second
            # at its upper — the cases real hypothesis shrinks toward
            for i in range(n):
                if i < 2 and all(named_strategies[k].boundaries
                                 for k in names):
                    drawn = {k: named_strategies[k].boundaries[i]
                             for k in names}
                else:
                    drawn = {k: named_strategies[k].example(rng)
                             for k in names}
                fn(**drawn)
        # pytest must not mistake the drawn parameters for fixtures
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__dict__["__wrapped__"]
        return wrapper
    return deco

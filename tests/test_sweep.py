"""Golden-value tests for the vectorized sweep engine (core.sweep): the
batched struct-of-arrays path must match the scalar dataclass path
element-for-element across sampled grids, for every topology, every metric,
device-corner axes, PCMC activation fractions, traffic broadcasting, and the
batched accelerator evaluator."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CNN_WORKLOADS,
    NetworkParams,
    Traffic,
    crosslight_25d_elec,
    crosslight_25d_siph,
    evaluate_accelerator,
    evaluate_accelerator_batch,
    evaluate_network,
    monolithic_crosslight,
    trine_network,
    tree_network,
)
from repro.core.sweep import (
    DEFAULT_TOPOLOGIES,
    METRIC_FIELDS,
    build_grid,
    evaluate_columns,
    network_columns,
    sweep,
    sweep_scalar_reference,
)

TRAFFIC = Traffic(bytes_read=2e8, bytes_written=7e7, n_transfers=320)

# the kernel is float32 unless jax_enable_x64; the scalar path is float64
RTOL = 1e-4

GRID_AXES = dict(
    n_gateways=(8, 16, 32, 64),
    n_lambda=(4, 8, 16),
    mem_bw_bytes_per_s=(50e9, 100e9, 200e9),
)


def _assert_metrics_match(res, ref):
    for k in METRIC_FIELDS:
        np.testing.assert_allclose(res.metrics[k], ref[k], rtol=RTOL,
                                   atol=0, err_msg=k)


@pytest.mark.parametrize("topology", list(DEFAULT_TOPOLOGIES))
def test_batched_matches_scalar_per_topology(topology):
    """Element-for-element parity on a 36-point grid, per topology (bus,
    tree, TRINE, electrical mesh)."""
    res = sweep(TRAFFIC, topologies=(topology,), **GRID_AXES)
    ref = sweep_scalar_reference(TRAFFIC, topologies=(topology,), **GRID_AXES)
    assert res.grid.n == 36
    _assert_metrics_match(res, ref)


def test_batched_matches_scalar_device_axes():
    """Dotted DeviceLibrary leaves are grid axes; parity must hold across
    device corners too."""
    axes = {"mzi.insertion_loss_db": (0.5, 1.0, 2.0),
            "mr.tuning_power_w": (137e-6, 275e-6, 550e-6)}
    res = sweep(TRAFFIC, topologies=("tree", "trine"), **axes)
    ref = sweep_scalar_reference(TRAFFIC, topologies=("tree", "trine"), **axes)
    _assert_metrics_match(res, ref)


def test_batched_matches_scalar_subnetwork_override():
    axes = dict(n_subnetworks=(1, 2, 4, 8, 16, 32))
    res = sweep(TRAFFIC, topologies=("trine",), **axes)
    ref = sweep_scalar_reference(TRAFFIC, topologies=("trine",), **axes)
    _assert_metrics_match(res, ref)


@pytest.mark.parametrize("frac", [0.4, 0.75, 1.0])
def test_batched_matches_scalar_active_fraction(frac):
    """PCMC gateway-activation fractions follow the identical rounding."""
    res = sweep(TRAFFIC, topologies=("trine", "sprint"),
                active_fraction=frac, n_lambda=(4, 8, 16))
    ref = sweep_scalar_reference(TRAFFIC, topologies=("trine", "sprint"),
                                 active_fraction=frac, n_lambda=(4, 8, 16))
    _assert_metrics_match(res, ref)


def test_traffic_broadcasting_matches_per_workload_calls():
    """(W, 1)-shaped traffic against an (N,) config axis gives (W, N)
    metrics equal to evaluating each workload separately."""
    grid = build_grid(("sprint", "tree", "trine"), n_lambda=(4, 8))
    nets = network_columns(grid)
    traffics = [CNN_WORKLOADS[n]().traffic() for n in ("LeNet5", "ResNet18")]
    bits = np.asarray([[t.total_bits] for t in traffics])
    xfers = np.asarray([[t.n_transfers] for t in traffics])
    both = evaluate_columns(nets, grid.cols, bits, xfers)
    assert both["latency_s"].shape == (2, grid.n)
    for wi, t in enumerate(traffics):
        one = evaluate_columns(nets, grid.cols, t.total_bits, t.n_transfers)
        for k in METRIC_FIELDS:
            np.testing.assert_allclose(both[k][wi], one[k], rtol=1e-6,
                                       err_msg=k)


def test_model_at_equals_scalar_factory():
    """A grid row reconstitutes to the identical NetworkModel dataclass the
    scalar factory builds."""
    res = sweep(TRAFFIC, topologies=("tree", "trine"))
    p = NetworkParams()
    assert res.model_at(0) == tree_network(p)
    assert res.model_at(1) == trine_network(p)


def test_scalar_row_reconstruction():
    grid = build_grid(("trine",), n_gateways=(16, 64),
                      **{"mzi.insertion_loss_db": (1.0, 2.0)})
    p = grid.row_params(3)
    assert isinstance(p.n_gateways, int) and p.n_gateways == 64
    d = grid.row_devices(3)
    assert d.mzi.insertion_loss_db == 2.0
    assert d.mr == grid.row_devices(0).mr  # unswept leaves untouched


def test_build_grid_rejects_unknown_axis_and_topology():
    with pytest.raises(KeyError):
        build_grid(("trine",), not_a_field=(1, 2))
    with pytest.raises(KeyError):
        build_grid(("warp-drive",))


def test_spacx_rejects_subcluster_gateway_counts():
    """g < 8 would mean zero SPACX clusters (zero bandwidth); both the
    batched kernel and the scalar wrapper must fail loudly, not emit inf."""
    with pytest.raises(ValueError):
        sweep(TRAFFIC, topologies=("spacx",), n_gateways=(4,))
    from repro.core import spacx_bus
    with pytest.raises(ValueError):
        spacx_bus(NetworkParams(n_gateways=4))


@pytest.mark.parametrize("accel_factory", [
    monolithic_crosslight, crosslight_25d_elec, crosslight_25d_siph])
@pytest.mark.parametrize("wl_name", ["LeNet5", "ResNet18"])
def test_accelerator_batch_matches_scalar(accel_factory, wl_name):
    """The batched per-layer accelerator evaluation reproduces the scalar
    layer loop for all three paper variants."""
    accel = accel_factory()
    wl = CNN_WORKLOADS[wl_name]()
    a = evaluate_accelerator(accel, wl)
    b = evaluate_accelerator_batch(accel, wl)
    for f in ("latency_s", "power_w", "energy_j", "epb_j", "compute_s",
              "network_s", "memory_s", "network_energy_j"):
        assert getattr(b, f) == pytest.approx(getattr(a, f), rel=RTOL), f


def test_accelerator_zero_unit_padding_parity():
    """Zero-unit chiplets (mix padding) must be inert on the scalar path,
    exactly as the vmapped kernel masks them: a padded accelerator scores
    identically to its unpadded twin, on both evaluation paths.  Regression:
    the scalar `_layer_compute` used to let a ChipletSpec(0, 1) row pollute
    slots_per_dot_best (vec=1 always wins the slot minimum)."""
    from repro.core import ChipletSpec
    wl = CNN_WORKLOADS["LeNet5"]()
    clean = crosslight_25d_siph()
    padded = dataclasses.replace(
        clean, chiplets=list(clean.chiplets) + [ChipletSpec(0, 1)])
    for f in ("latency_s", "power_w", "energy_j", "epb_j", "compute_s",
              "network_s", "memory_s", "network_energy_j"):
        assert getattr(evaluate_accelerator(padded, wl), f) == \
            getattr(evaluate_accelerator(clean, wl), f), f
        assert getattr(evaluate_accelerator_batch(padded, wl), f) == \
            pytest.approx(getattr(evaluate_accelerator_batch(clean, wl), f),
                          rel=RTOL), f


def test_accelerator_all_zero_mix_raises():
    """An all-zero chiplet mix has no compute throughput: both the scalar
    path and the batched mix-columns builder must fail loudly instead of
    dividing by zero."""
    from repro.core import ChipletSpec
    from repro.core.accelerator import chiplet_mix_columns
    wl = CNN_WORKLOADS["LeNet5"]()
    clean = crosslight_25d_siph()
    dead = dataclasses.replace(
        clean, chiplets=[ChipletSpec(0, 9), ChipletSpec(0, 49)])
    with pytest.raises(ValueError, match="no active"):
        evaluate_accelerator(dead, wl)
    with pytest.raises(ValueError, match="no active"):
        chiplet_mix_columns([[ChipletSpec(512, 32)], [ChipletSpec(0, 9)]])

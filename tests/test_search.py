"""Tests for the Pareto/co-design search engine (core.search) and the
chunked streaming evaluator (core.sweep.sweep_chunked):

  * jitted O(n log n) front extraction == O(n^2) brute force, on random
    clouds with ties/duplicates and on real sweep metrics for every topology
  * chunked streaming evaluation == monolithic evaluation, element for
    element, including the padded last chunk and multi-workload batching
  * merge-fronts associativity (front(A ∪ B) == front(front A ∪ front B))
  * co-design (network x chiplet-mix) front == brute force over the joint
    grid
  * jax.grad through the xp-generic topology kernels == float64 central
    finite differences of the scalar dataclass path
"""

import functools
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    CNN_WORKLOADS,
    ChipletSpec,
    NetworkParams,
    Traffic,
    evaluate_network,
)
from repro.core.devices import DEFAULT_DEVICES, replace_device_leaves
from repro.core.topology import TOPOLOGIES, TOPOLOGY_ARRAYS
from repro.core.power import EVAL_DEVICE_FIELDS, eval_network_math
from repro.core.sweep import (
    DEFAULT_TOPOLOGIES,
    ChunkReducer,
    MinReducer,
    build_grid,
    grid_spec,
    sweep,
    sweep_chunked,
)
from repro.core.search import (
    OBJECTIVES,
    ParetoFront,
    _coordinate_int_search,
    _trust_region_descent,
    codesign_pareto,
    merge_fronts,
    pareto_front,
    pareto_mask,
    pareto_mask_reference,
    pareto_search,
    refine_codesign,
    refine_continuous,
    refine_front,
    refine_front_point,
    refine_trust_region,
)

TRAFFIC = Traffic(bytes_read=2e8, bytes_written=7e7, n_transfers=320)

GRID_AXES = dict(
    n_gateways=(8, 16, 32, 64),
    n_lambda=(4, 8, 16),
    mem_bw_bytes_per_s=(50e9, 100e9, 200e9),
)


# ---------------------------------------------------------------------------
# pareto_mask vs brute force
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [2, 3])
@pytest.mark.parametrize("n", [1, 2, 3, 17, 400, 5000])
def test_pareto_mask_matches_bruteforce_random(m, n):
    rng = np.random.default_rng(n * 10 + m)
    pts = rng.normal(size=(n, m))
    assert np.array_equal(pareto_mask(pts), pareto_mask_reference(pts))


@pytest.mark.parametrize("m", [2, 3])
def test_pareto_mask_matches_bruteforce_ties_and_duplicates(m):
    rng = np.random.default_rng(7)
    # coarse integer grid => many per-objective ties and exact duplicates
    pts = rng.integers(0, 5, size=(600, m)).astype(float)
    mask, ref = pareto_mask(pts), pareto_mask_reference(pts)
    assert np.array_equal(mask, ref)
    # exact duplicates never dominate each other: all copies share a verdict
    dup = np.concatenate([pts, pts[:25]], axis=0)
    mask2 = pareto_mask(dup)
    assert np.array_equal(mask2[:600][:25] if False else mask2[600:],
                          mask2[:25])
    assert np.array_equal(mask2, pareto_mask_reference(dup))


def test_pareto_mask_all_identical_points_all_on_front():
    pts = np.ones((37, 3))
    assert pareto_mask(pts).all()


def test_pareto_mask_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pareto_mask(np.zeros((4, 5)))
    assert pareto_mask(np.zeros((0, 3))).shape == (0,)


@pytest.mark.parametrize("topology", list(DEFAULT_TOPOLOGIES))
def test_front_on_real_sweep_metrics_per_topology(topology):
    """Front of real (latency, energy, power) sweep metrics == brute force,
    for every topology family including the electrical mesh."""
    res = sweep(TRAFFIC, topologies=(topology,), **GRID_AXES)
    front = pareto_front(res)
    pts = np.stack([res.metrics[k] for k in OBJECTIVES], -1)
    ref_idx = set(np.where(pareto_mask_reference(pts))[0].tolist())
    assert set(front.indices.tolist()) == ref_idx
    assert front.objectives == OBJECTIVES


def test_merge_fronts_associativity():
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(900, 3))
    idx = np.arange(900)
    whole = merge_fronts(ParetoFront(OBJECTIVES, pts, idx))
    parts = [ParetoFront(OBJECTIVES, pts[s:s + 300], idx[s:s + 300])
             for s in (0, 300, 600)]
    part_fronts = [merge_fronts(p) for p in parts]
    merged = merge_fronts(*part_fronts)
    assert np.array_equal(whole.points, merged.points)
    assert np.array_equal(whole.indices, merged.indices)


# ---------------------------------------------------------------------------
# chunked streaming == monolithic
# ---------------------------------------------------------------------------


class _CollectReducer(ChunkReducer):
    """Test-only: concatenates every chunk's metrics (NOT bounded memory)."""

    def step(self, carry, chunk):
        carry = carry or []
        carry.append(chunk.metrics)
        return carry

    def finish(self, carry, spec):
        return {k: np.concatenate([c[k] for c in carry], axis=-1)
                for k in carry[0]}


@pytest.mark.parametrize("chunk_size", [1, 7, 64, 10_000])
def test_chunked_matches_monolithic(chunk_size):
    """Streaming chunks (including the repeat-padded last one) reproduce the
    monolithic metrics element for element."""
    res = sweep(TRAFFIC, **GRID_AXES)
    got = sweep_chunked(TRAFFIC, _CollectReducer(), chunk_size=chunk_size,
                        **GRID_AXES)
    for k, v in res.metrics.items():
        np.testing.assert_allclose(got[k], v, rtol=1e-15, err_msg=k)


def test_chunked_multi_workload_and_min_reducer():
    traffics = [CNN_WORKLOADS[n]().traffic() for n in ("LeNet5", "ResNet18")]
    got = sweep_chunked(traffics, _CollectReducer(), chunk_size=13,
                        **GRID_AXES)
    best = sweep_chunked(traffics, MinReducer("energy_j"), chunk_size=13,
                         **GRID_AXES)
    assert got["latency_s"].shape[0] == 2
    for w, t in enumerate(traffics):
        ref = sweep(t, **GRID_AXES)
        np.testing.assert_allclose(got["energy_j"][w], ref.metrics["energy_j"],
                                   rtol=1e-15)
        i, _ = ref.best("energy_j")
        assert int(best["index"][w]) == i


def test_streaming_pareto_matches_monolithic_and_bruteforce():
    res = sweep(TRAFFIC, **GRID_AXES)
    mono = pareto_front(res)
    stream = pareto_search(TRAFFIC, chunk_size=61, **GRID_AXES)
    assert np.array_equal(mono.points, stream.points)
    assert np.array_equal(mono.indices, stream.indices)
    pts = np.stack([res.metrics[k] for k in OBJECTIVES], -1)
    assert set(stream.indices.tolist()) == set(
        np.where(pareto_mask_reference(pts))[0].tolist())
    cfg = stream.configs(grid_spec(**GRID_AXES))[0]
    assert cfg["topology"] in DEFAULT_TOPOLOGIES


def test_pareto_search_multi_workload_returns_per_workload_fronts():
    traffics = [CNN_WORKLOADS[n]().traffic() for n in ("LeNet5", "VGG16")]
    fronts = pareto_search(traffics, chunk_size=40, **GRID_AXES)
    assert isinstance(fronts, list) and len(fronts) == 2
    for w, t in enumerate(traffics):
        mono = pareto_front(sweep(t, **GRID_AXES))
        assert np.array_equal(fronts[w].points, mono.points)


def test_chunked_shard_flag_single_device_noop():
    """shard=True must be a no-op (same results) on a single device; on
    multi-device hosts it lays chunk columns across devices."""
    a = sweep_chunked(TRAFFIC, _CollectReducer(), chunk_size=50, shard=True,
                      **GRID_AXES)
    b = sweep_chunked(TRAFFIC, _CollectReducer(), chunk_size=50, shard=False,
                      **GRID_AXES)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-15, err_msg=k)


def test_chunked_shard_multi_device_subprocess():
    """Real NamedSharding coverage: 4 simulated host devices (subprocess so
    the XLA flag applies), chunk size rounded up to a device multiple, and
    the sharded streaming argmin must match the monolithic sweep."""
    import subprocess
    import sys
    from pathlib import Path
    code = (
        "import jax, numpy as np\n"
        "assert jax.device_count() == 4, jax.device_count()\n"
        "from repro.core import CNN_WORKLOADS\n"
        "from repro.core.sweep import sweep, sweep_chunked, MinReducer\n"
        "t = CNN_WORKLOADS['ResNet18']().traffic()\n"
        "axes = dict(n_gateways=(8, 16, 32, 64), n_lambda=(2, 4, 8, 16))\n"
        "res = sweep(t, **axes)\n"
        "i, _ = res.best('energy_j')\n"
        "out = sweep_chunked(t, MinReducer('energy_j'), chunk_size=37,\n"
        "                    shard=True, **axes)\n"
        "assert out['index'] == i, (out['index'], i)\n"
        "assert abs(out['value'] - res.metrics['energy_j'][i]) < 1e-12\n")
    env = dict(os.environ)
    repo = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = (str(repo / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]


def test_grid_spec_chunks_match_build_grid():
    spec = grid_spec(("tree", "trine"), n_gateways=(16, 32),
                     **{"mzi.insertion_loss_db": (0.5, 1.0, 2.0)})
    grid = build_grid(("tree", "trine"), n_gateways=(16, 32),
                      **{"mzi.insertion_loss_db": (0.5, 1.0, 2.0)})
    assert spec.n == grid.n
    cols, topo_id = spec.chunk_cols(5, 11)
    assert np.array_equal(topo_id, grid.topo_id[5:11])
    for k in grid.cols:
        assert np.array_equal(cols[k], grid.cols[k][5:11]), k
    for i in (0, 5, grid.n - 1):
        cfg = spec.config_at(i)
        assert cfg["topology"] == grid.row_topology(i)
        assert cfg["n_gateways"] == grid.cols["n_gateways"][i]


# ---------------------------------------------------------------------------
# co-design grid search
# ---------------------------------------------------------------------------


def test_codesign_front_matches_bruteforce():
    wl = CNN_WORKLOADS["LeNet5"]()
    mixes = [[ChipletSpec(512, 32)],
             [ChipletSpec(512, 9), ChipletSpec(512, 49)],
             [ChipletSpec(256, 16), ChipletSpec(256, 64),
              ChipletSpec(128, 128)]]
    axes = dict(n_gateways=(16, 32), n_lambda=(4, 8))
    front, spec = codesign_pareto(
        wl, mixes, topologies=("trine", "tree", "elec"), chunk_size=5, **axes)
    # brute force over the joint (mix x config) grid
    from repro.core.accelerator import evaluate_accelerator_grid
    from repro.core.sweep import _network_columns_arrays
    cols, topo_id = spec.chunk_cols(0, spec.n)
    nets = _network_columns_arrays(cols, topo_id, spec.topologies)
    out = evaluate_accelerator_grid(
        wl, mixes, nets, cols,
        cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"])
    pts = np.stack([out[k] for k in OBJECTIVES], -1).reshape(-1, 3)
    assert set(front.indices.tolist()) == set(
        np.where(pareto_mask_reference(pts))[0].tolist())
    # padded-mix kernel: the 1-chiplet mix must behave as if unpadded
    assert out["latency_s"].shape == (3, spec.n)


def test_accelerator_grid_device_corner_sweep_scalar_nets():
    """(N,) device columns with scalar network fields must broadcast: a
    device-corner sweep at a fixed network is a supported grid shape."""
    from repro.core.accelerator import evaluate_accelerator_grid
    from repro.core.devices import device_columns
    from repro.core.topology import MODEL_FIELDS
    from repro.core import trine_network
    wl = CNN_WORKLOADS["LeNet5"]()
    net = trine_network(NetworkParams())
    nets = {f: np.float64(getattr(net, f)) for f in MODEL_FIELDS}
    dev = dict(device_columns())
    dev["mr.tuning_power_w"] = np.asarray([137e-6, 275e-6, 550e-6])
    out = evaluate_accelerator_grid(wl, [[ChipletSpec(512, 32)]], nets, dev,
                                    100e9)
    assert out["latency_s"].shape == (1, 3)
    # more trimming power per MR -> network energy must not decrease
    assert np.all(np.diff(out["network_energy_j"][0]) >= 0)


# ---------------------------------------------------------------------------
# gradient refinement
# ---------------------------------------------------------------------------


def _scalar_log_edp(topology, traffic, **overrides):
    """float64 scalar-dataclass-path log(EDP) — the FD reference."""
    dev_leaves = {k: v for k, v in overrides.items() if "." in k}
    params = {k: v for k, v in overrides.items() if "." not in k}
    p = NetworkParams(**params)
    d = replace_device_leaves(DEFAULT_DEVICES, dev_leaves)
    net = TOPOLOGIES[topology](p, d=d)
    rep = evaluate_network(net, traffic, d)
    return np.log(rep.energy_j) + np.log(rep.latency_s)


@pytest.mark.parametrize("axis,x0", [
    ("modulation_rate_bps", 12e9),
    ("mem_bw_bytes_per_s", 100e9),
    ("mzi.insertion_loss_db", 1.0),
])
def test_grad_matches_finite_differences(axis, x0):
    """One jax.grad step through the xp-generic trine kernel equals a
    float64 central finite difference of the scalar reference path (in
    log-log space, away from ceil/round quantization boundaries)."""
    spec = grid_spec(("trine",))
    cols = dict(spec.base)

    def loss(theta):
        c = {k: jnp.asarray(v) for k, v in cols.items()}
        c[axis] = jnp.exp(theta)
        fields = TOPOLOGY_ARRAYS["trine"](c, xp=jnp)
        dev = {k: c[k] for k in EVAL_DEVICE_FIELDS}
        m = eval_network_math(fields, dev, jnp.asarray(TRAFFIC.total_bits),
                              jnp.asarray(float(TRAFFIC.n_transfers)),
                              jnp.asarray(1.0))
        return jnp.log(m["energy_j"]) + jnp.log(m["latency_s"])

    theta0 = float(np.log(x0))
    g = float(jax.grad(loss)(jnp.asarray(theta0, jnp.float32)))
    h = 0.02
    f_hi = _scalar_log_edp("trine", TRAFFIC, **{axis: float(np.exp(theta0 + h))})
    f_lo = _scalar_log_edp("trine", TRAFFIC, **{axis: float(np.exp(theta0 - h))})
    fd = (f_hi - f_lo) / (2 * h)
    assert g == pytest.approx(fd, rel=5e-2, abs=5e-3), (g, fd)


def _relaxed_accel_log_edp(axis, j, value):
    """log-EDP of the relaxed accelerator kernel with one accelerator axis
    overridden by (traced) `value` — the loss `refine_codesign` descends.
    mac_rate is tiny so compute binds and the accelerator axes genuinely
    carry gradient; adaptive PCMC is off so the FD interval crosses no
    activation-step quantization boundary."""
    from repro.core.accelerator import _accel_mix_math, layer_columns
    from repro.core.topology import MODEL_FIELDS
    wl = CNN_WORKLOADS["LeNet5"]()
    spec = grid_spec(("trine",))
    cols = {k: jnp.asarray(np.float64(v)) for k, v in spec.base.items()}
    lc = {k: jnp.asarray(v) for k, v in layer_columns(wl).items()}
    units = jnp.asarray(np.asarray([96.0, 48.0]))
    vec = jnp.asarray(np.asarray([9.0, 49.0]))
    mac = jnp.asarray(np.float64(1e8))
    slot = jnp.asarray(np.float64(30e-15))
    if axis == "n_units":
        units = units.at[j].set(value)
    elif axis == "vector_size":
        vec = vec.at[j].set(value)
    elif axis == "mac_rate_hz":
        mac = value
    else:
        slot = value
    fields = TOPOLOGY_ARRAYS["trine"](cols, xp=jnp)
    nets1 = {k: jnp.reshape(fields[k], (1,)) for k in MODEL_FIELDS}
    dev1 = {k: jnp.reshape(cols[k], (1,)) for k in EVAL_DEVICE_FIELDS}
    mem_bw1 = jnp.reshape(
        cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"], (1,))
    m = _accel_mix_math({"n_units": units, "vector_size": vec}, None, lc,
                        nets1, dev1, mem_bw1, mac, slot,
                        jnp.asarray(np.float64(16.0)),
                        adaptive=False, relaxed=True)
    return jnp.log(m["energy_j"][0]) + jnp.log(m["latency_s"][0])


@pytest.mark.parametrize("axis,j,x0", [
    ("n_units", 0, 96.0),
    ("n_units", 1, 48.0),
    ("vector_size", 0, 9.0),
    ("mac_rate_hz", None, 1e8),
    ("lambda_slot_energy_j", None, 30e-15),
])
def test_relaxed_accel_grad_matches_finite_differences(axis, j, x0):
    """jax.grad through the relaxed accelerator kernel (max(L/V, 1) pass
    count) equals float64 central finite differences of the same relaxed
    function, for every relaxable accelerator axis — mirroring the network-
    axis gradient checks above."""
    from jax.experimental import enable_x64

    def loss(theta):
        return _relaxed_accel_log_edp(axis, j, jnp.exp(theta))

    theta0 = float(np.log(x0))
    g = float(jax.grad(loss)(jnp.asarray(theta0, jnp.float32)))
    h = 0.02
    with enable_x64():
        f_hi = float(loss(jnp.asarray(theta0 + h, jnp.float64)))
        f_lo = float(loss(jnp.asarray(theta0 - h, jnp.float64)))
    fd = (f_hi - f_lo) / (2 * h)
    assert g == pytest.approx(fd, rel=5e-2, abs=5e-3), (g, fd)
    if axis in ("n_units", "mac_rate_hz"):
        # compute-bound by construction: these axes must genuinely move EDP
        assert abs(fd) > 1e-3, fd


def test_refine_continuous_improves_and_respects_bounds():
    t = CNN_WORKLOADS["ResNet18"]().traffic()
    r = refine_continuous("trine", {"n_gateways": 32}, t, steps=25, lr=0.1,
                          span=4.0)
    assert r["refined_value"] <= r["start_value"]
    for nm, v in r["refined"].items():
        lo, hi = r["start"][nm] / 4.0, r["start"][nm] * 4.0
        assert lo * (1 - 1e-9) <= v <= hi * (1 + 1e-9), nm
    assert set(r["metrics"]) >= {"latency_s", "energy_j", "power_w"}


def test_refine_front_point_from_pareto_search():
    t = CNN_WORKLOADS["ResNet18"]().traffic()
    axes = dict(n_gateways=(16, 32), n_lambda=(4, 8))
    front = pareto_search(t, topologies=("trine", "tree"), **axes)
    spec = grid_spec(("trine", "tree"), **axes)
    r = refine_front_point(spec, t, int(front.indices[0]), steps=10, lr=0.1)
    assert r["refined_value"] <= r["start_value"]
    assert r["topology"] in ("trine", "tree")


# ---------------------------------------------------------------------------
# guards: empty grids / mixes and eager objective validation
# ---------------------------------------------------------------------------


def test_codesign_pareto_empty_grid_and_mixes_raise():
    """Regression: an empty grid used to reach range(0, 0, 0) deep in the
    chunk loop (ValueError: range() arg 3 must not be zero); empty mixes
    crashed inside the mix-column builder.  Both must fail up front."""
    wl = CNN_WORKLOADS["LeNet5"]()
    mixes = [[ChipletSpec(256, 9)]]
    with pytest.raises(ValueError, match="empty grid"):
        codesign_pareto(wl, mixes, n_gateways=())
    with pytest.raises(ValueError, match="empty grid"):
        codesign_pareto(wl, mixes, topologies=())
    with pytest.raises(ValueError, match="chiplet mix"):
        codesign_pareto(wl, [])


def test_refine_objective_validated_eagerly():
    """Regression: an unknown objective used to surface as a bare KeyError
    from deep inside the jitted loss; both refiners must reject it before
    tracing, naming the valid vocabulary."""
    t = CNN_WORKLOADS["LeNet5"]().traffic()
    with pytest.raises(ValueError, match="valid objectives"):
        refine_continuous("trine", {}, t, objective="edp_j")
    wl, mixes, front, spec = _codesign_refine_setup()
    with pytest.raises(ValueError, match="valid objectives"):
        refine_codesign(spec, mixes, wl, int(front.indices[0]),
                        objective="edp_j")
    # metric objectives from each vocabulary still work
    r = refine_continuous("trine", {}, t, objective="power_w", steps=2)
    assert r["objective"] == "power_w"


# ---------------------------------------------------------------------------
# co-design refinement: relaxed descent + round-and-rescore
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _codesign_refine_setup():
    wl = CNN_WORKLOADS["LeNet5"]()
    mixes = [[ChipletSpec(256, 9), ChipletSpec(128, 49)],
             [ChipletSpec(512, 32)],
             [ChipletSpec(128, 9), ChipletSpec(128, 27),
              ChipletSpec(64, 128)]]
    axes = dict(n_gateways=(16, 32), n_lambda=(4, 8))
    front, spec = codesign_pareto(wl, mixes, topologies=("trine", "tree"),
                                  chunk_size=7, **axes)
    return wl, mixes, front, spec


def test_refine_codesign_round_and_rescore_feasible_and_exact():
    """The refined point is always a feasible integer design, and its
    reported metrics are bit-identical to a standalone exact re-score of
    the refined config through `evaluate_accelerator_grid`."""
    from repro.core.accelerator import evaluate_accelerator_grid
    from repro.core.sweep import _network_columns_arrays
    wl, mixes, front, spec = _codesign_refine_setup()
    r = refine_codesign(spec, mixes, wl, int(front.indices[0]), steps=8)
    cfg = r["refined"]["config"]
    for c in cfg["chiplets"]:
        assert isinstance(c.n_units, int) and isinstance(c.vector_size, int)
        assert c.vector_size >= 1 and c.n_units >= 0
    assert any(c.n_units > 0 for c in cfg["chiplets"])
    # grid axes the refiner does not touch keep admissible integer values
    for nm in ("n_gateways", "n_lambda"):
        assert cfg[nm] == float(int(cfg[nm]))
    cols = {k: np.full(1, v, np.float64) for k, v in spec.base.items()}
    for k, v in cfg.items():
        if k in cols:
            cols[k][:] = float(v)
    nets = _network_columns_arrays(cols, np.zeros(1, np.int64),
                                   (cfg["topology"],))
    out = evaluate_accelerator_grid(
        wl, [cfg["chiplets"]], nets, cols,
        cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"],
        mac_rate_hz=cfg["mac_rate_hz"],
        lambda_slot_energy_j=cfg["lambda_slot_energy_j"])
    for k, v in r["refined"]["metrics"].items():
        assert float(out[k][0, 0]) == v, k


def test_refine_codesign_improves_at_least_one_seed():
    """Acceptance: on >= 3 frontier seeds the refiner returns feasible
    integer designs, never worse than the seed, strictly better on at
    least one."""
    wl, mixes, front, spec = _codesign_refine_setup()
    order = np.argsort(front.points[:, 0] * front.points[:, 1])
    results = [refine_codesign(spec, mixes, wl, int(front.indices[i]),
                               steps=12)
               for i in order[:3]]
    for r in results:
        for c in r["refined"]["chiplets"]:
            assert isinstance(c.n_units, int)
            assert isinstance(c.vector_size, int)
        assert r["refined"]["value"] <= r["seed"]["value"]
        assert r["improvement"] >= 0.0
        assert set(r["sensitivity"]) >= {"modulation_rate_bps",
                                         "mac_rate_hz"}
    assert any(r["improvement"] > 0 for r in results)


def test_refine_front_dominates_seed_and_configs_roundtrip():
    """Property: the merged refined front weakly dominates the seed front
    (checked against the O(n^2) reference), and every merged row decodes to
    a config (refined rows to their refined design)."""
    wl, mixes, front, spec = _codesign_refine_setup()
    out = refine_front(front, spec, mixes, wl, top_k=3, steps=6)
    merged, seed = out["front"], out["seed_front"]
    union = np.concatenate([merged.points, seed.points])
    seed_on_union = pareto_mask_reference(union)[merged.size:]
    seed_present = np.array([bool((merged.points == p).all(-1).any())
                             for p in seed.points])
    assert np.all(~seed_on_union | seed_present)
    assert len(out["configs"]) == merged.size
    for cfg in out["configs"]:
        assert cfg["topology"] in ("trine", "tree")
        assert "chiplets" in cfg
    assert 0 <= out["n_improved"] <= len(out["results"])
    # sensitivities cover both network and accelerator axes
    assert set(out["sensitivity"]) >= {"modulation_rate_bps",
                                       "lambda_slot_energy_j"}


# ---------------------------------------------------------------------------
# second-order refinement: trust-region descent + integer line search
# ---------------------------------------------------------------------------


def test_trust_region_descent_exact_quadratic_converges():
    """On an anisotropic quadratic with its exact Hessian the loop takes
    pure accepted Newton steps (the model is exact, so rho == 1, nothing
    is ever rejected) and reaches the minimizer."""
    A = np.diag([1.0, 25.0])
    c = np.array([0.4, -0.7])

    def vg(x):
        d = np.asarray(x, np.float64) - c
        return 0.5 * float(d @ A @ d), A @ d

    lo, hi = np.full(2, -3.0), np.full(2, 3.0)
    best, theta, trace, g0, st = _trust_region_descent(
        vg, lambda x: A, np.zeros(2), lo, hi, steps=12)
    assert best == trace[-1] <= trace[0]
    assert np.allclose(theta, c, atol=1e-6)
    assert best == pytest.approx(0.0, abs=1e-10)
    assert st["rejected"] == 0 and st["accepted"] >= 1
    assert np.allclose(g0, -A @ c)  # float64 gradient at the seed


def test_trust_region_rejects_lying_gradient_and_shrinks_radius():
    """A gradient that points uphill makes every proposed step increase
    the exact objective: each one must be rejected on the exact re-score,
    the radius must shrink strictly after every rejection until it
    collapses, and the returned design is the untouched seed — the
    never-worse-than-seed guarantee under a hostile model."""
    def vg(x):
        x = np.asarray(x, np.float64)
        return float(x @ x), -2.0 * x  # honest value, lying gradient

    x0 = np.array([1.0, -1.5])
    best, theta, trace, _, st = _trust_region_descent(
        vg, lambda x: 2.0 * np.eye(2), x0,
        np.full(2, -4.0), np.full(2, 4.0), steps=30)
    assert st["accepted"] == 0 and st["rejected"] >= 3
    rt = st["radius_trace"]
    assert len(rt) == st["rejected"]
    assert all(b < a for a, b in zip(rt, rt[1:]))  # strictly shrinking
    assert st["stopped_early"] and st["final_radius"] < 1e-5
    assert best == trace[0] and len(trace) == 1
    assert np.array_equal(theta, x0)  # never worse than the seed


def test_trust_region_pins_against_box():
    """A minimizer outside the box: the loop walks to the boundary, then
    stops early once the box admits no further move, reporting the clipped
    boundary point."""
    def vg(x):
        d = np.asarray(x, np.float64) - 10.0
        return float(d @ d), 2.0 * d

    best, theta, trace, _, st = _trust_region_descent(
        vg, lambda x: 2.0 * np.eye(2), np.zeros(2),
        np.full(2, -1.0), np.full(2, 1.0), steps=20, radius=0.5)
    assert np.allclose(theta, 1.0)  # pinned at the upper corner
    assert st["stopped_early"]
    assert best == pytest.approx(2 * 81.0)


def test_coordinate_int_search_separable_optimum_and_memoization():
    """Separable convex scores: the walk reaches the exact integer optimum
    and the memo cache guarantees each design is scored exactly once."""
    calls = []

    def score(v):
        calls.append(1)
        return (v["a"] - 7) ** 2 + (v["b"] - 3) ** 2

    best, val, st = _coordinate_int_search(
        {"a": 2, "b": 10}, {"a": 1, "b": 1}, {"a": 16, "b": 16}, score)
    assert best == {"a": 7, "b": 3} and val == 0.0
    assert st["n_scored"] == len(calls)  # never re-scored
    assert st["n_sweeps"] >= 2


def test_coordinate_int_search_bounds_and_infeasible():
    """Bounds clamp the walk and +inf marks infeasible designs: the search
    settles on the best reachable feasible design, never leaving the box."""
    def score(v):
        if v["a"] + v["b"] > 9:
            return float("inf")
        return -(v["a"] + v["b"])

    best, val, st = _coordinate_int_search(
        {"a": 4, "b": 4}, {"a": 1, "b": 1}, {"a": 6, "b": 6}, score)
    assert best["a"] + best["b"] == 9 and val == -9.0
    assert 1 <= best["a"] <= 6 and 1 <= best["b"] <= 6


TR_AXES = ("modulation_rate_bps", "mem_bw_bytes_per_s",
           "interposer_side_cm", "n_gateways")


def test_refine_codesign_trust_region_never_worse_and_rescores_exact():
    """method="trust_region": the refined point is a feasible integer
    design, never worse than its seed, and its reported metrics re-score
    bit-identically through a standalone `evaluate_accelerator_grid` call
    — the same exactness contract the first-order engine is held to."""
    from repro.core.accelerator import evaluate_accelerator_grid
    from repro.core.sweep import _network_columns_arrays
    wl, mixes, front, spec = _codesign_refine_setup()
    r = refine_trust_region(spec, mixes, wl, int(front.indices[0]),
                            steps=6, refine_axes=TR_AXES)
    assert r["method"] == "trust_region"
    assert r["refined"]["value"] <= r["seed"]["value"]
    assert r["improvement"] >= 0.0
    st = r["tr_stats"]
    assert st["accepted"] + st["rejected"] == len(st["radius_trace"]) <= 6
    cfg = r["refined"]["config"]
    for c in cfg["chiplets"]:
        assert isinstance(c.n_units, int) and isinstance(c.vector_size, int)
    assert any(c.n_units > 0 for c in cfg["chiplets"])
    assert cfg["n_gateways"] == float(int(cfg["n_gateways"]))
    cols = {k: np.full(1, v, np.float64) for k, v in spec.base.items()}
    for k, v in cfg.items():
        if k in cols:
            cols[k][:] = float(v)
    nets = _network_columns_arrays(cols, np.zeros(1, np.int64),
                                   (cfg["topology"],))
    out = evaluate_accelerator_grid(
        wl, [cfg["chiplets"]], nets, cols,
        cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"],
        mac_rate_hz=cfg["mac_rate_hz"],
        lambda_slot_energy_j=cfg["lambda_slot_energy_j"])
    for k, v in r["refined"]["metrics"].items():
        assert float(out[k][0, 0]) == v, k


def test_refine_codesign_tr_line_search_dominates_snap():
    """The integer line search is seeded at the floor/ceil snap winner, so
    its value weakly dominates the snap value on every seed; it must also
    actually explore (score additional integer designs) somewhere across
    three frontier seeds."""
    wl, mixes, front, spec = _codesign_refine_setup()
    order = np.argsort(front.points[:, 0] * front.points[:, 1])
    searches = []
    for i in order[:3]:
        r = refine_trust_region(spec, mixes, wl, int(front.indices[i]),
                                steps=4, refine_axes=TR_AXES)
        assert r["refined"]["value"] <= r["seed"]["value"]
        searches.append(r["line_search"])
    for s in searches:
        assert s["value"] <= s["snap_value"]
    assert any(s["n_scored"] > 1 for s in searches)


def test_refine_codesign_multiworkload_geomean_and_per_workload_rescore():
    """Joint refinement over two weighted workloads: per-workload exact
    metrics come back for seed and refined designs, the combined value is
    their weighted geometric mean, each per-workload dict re-scores
    bit-identically, and malformed weights are rejected eagerly."""
    from repro.core.accelerator import evaluate_accelerator_grid
    from repro.core.sweep import _network_columns_arrays
    wl, mixes, front, spec = _codesign_refine_setup()
    wls = [wl, CNN_WORKLOADS["ResNet18"]()]
    r = refine_trust_region(spec, mixes, wls, int(front.indices[0]),
                            steps=4, refine_axes=TR_AXES,
                            weights=(3.0, 1.0))
    assert r["workloads"] == [w.name for w in wls]
    assert r["weights"] == pytest.approx([0.75, 0.25])
    for blk in (r["seed"], r["refined"]):
        assert len(blk["per_workload"]) == 2
        edps = [m["energy_j"] * m["latency_s"] for m in blk["per_workload"]]
        geo = float(np.exp(0.75 * np.log(edps[0]) + 0.25 * np.log(edps[1])))
        assert blk["value"] == pytest.approx(geo, rel=1e-12)
    cfg = r["refined"]["config"]
    cols = {k: np.full(1, v, np.float64) for k, v in spec.base.items()}
    for k, v in cfg.items():
        if k in cols:
            cols[k][:] = float(v)
    nets = _network_columns_arrays(cols, np.zeros(1, np.int64),
                                   (cfg["topology"],))
    for w, per in zip(wls, r["refined"]["per_workload"]):
        out = evaluate_accelerator_grid(
            w, [cfg["chiplets"]], nets, cols,
            cols["n_mem_chiplets"] * cols["mem_bw_bytes_per_s"],
            mac_rate_hz=cfg["mac_rate_hz"],
            lambda_slot_energy_j=cfg["lambda_slot_energy_j"])
        for k, v in per.items():
            assert float(out[k][0, 0]) == v, (w.name, k)
    with pytest.raises(ValueError, match="weights"):
        refine_codesign(spec, mixes, wls, int(front.indices[0]),
                        weights=(1.0,))
    with pytest.raises(ValueError, match="positive"):
        refine_codesign(spec, mixes, wls, int(front.indices[0]),
                        weights=(1.0, -1.0))


def test_refine_codesign_method_validated_eagerly():
    wl, mixes, front, spec = _codesign_refine_setup()
    with pytest.raises(ValueError, match="method"):
        refine_codesign(spec, mixes, wl, int(front.indices[0]),
                        method="newton")


def test_refine_continuous_metrics_describe_clipped_design():
    """Regression: with a tight box the projection is active at the end of
    the descent, and the reported metrics used to be evaluated at the
    pre-clip iterate — silently describing a different design than the
    reported one.  The metrics must re-evaluate, at the reported refined
    values, to the reported numbers."""
    t = CNN_WORKLOADS["LeNet5"]().traffic()
    axes = ("modulation_rate_bps", "mem_bw_bytes_per_s")
    probe = refine_continuous("trine", {}, t, refine_axes=axes, steps=0)
    tight = {nm: (v * 0.999, v * 1.001) for nm, v in probe["start"].items()}
    r = refine_continuous("trine", {}, t, refine_axes=axes, steps=10,
                          lr=0.5, bounds=tight)
    assert r["refined_value"] <= r["start_value"] * (1 + 1e-12)
    # the big log-space steps pin at least one axis against the tight box
    # (projection happens in float32 log-space, so "at the bound" means
    # within float32 resolution of it, not bit-exactly on it)
    at_bound = [nm for nm, v in r["refined"].items()
                if min(abs(v - tight[nm][0]),
                       abs(v - tight[nm][1])) <= 1e-5 * v]
    assert at_bound, r["refined"]
    # re-evaluate the metrics AT the reported design via a steps=0 probe
    r2 = refine_continuous("trine", dict(r["refined"]), t, refine_axes=axes,
                           steps=0)
    for k, v in r["metrics"].items():
        assert r2["metrics"][k] == pytest.approx(v, rel=1e-9), k

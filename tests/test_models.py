"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, decode consistency, and the photonic
MAC numerics as a model feature."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import model as M


def _batch(cfg, b=2, s=64, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    if cfg.frontend == "vision":
        batch["pixel_embeds"] = jax.random.normal(key, (b, 16, cfg.d_model))
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(key, (b, s // 4, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = C.get_reduced(arch)
    params, specs = M.init(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: x is None or isinstance(x, tuple))
    batch = _batch(cfg)
    logits = M.train_logits(cfg, params, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = C.get_reduced(arch)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = {k: v for k, v in _batch(cfg, b, s).items() if k != "labels"}
    batch.pop("pixel_embeds", None)
    logits, cache = M.prefill(cfg, params, batch, cache_len=s + 4)
    assert logits.shape == (b, 1, cfg.vocab)
    enc_out = (M.encode(cfg, params, batch["enc_embeds"])
               if cfg.encoder_layers else None)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg, cache = M.serve_step(cfg, params, cache, tok, jnp.int32(s), enc_out=enc_out)
    assert lg.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ["yi_6b", "gemma3_27b", "xlstm_350m",
                                  "zamba2_1p2b", "seamless_m4t_medium"])
def test_decode_matches_forward(arch):
    cfg = C.get_reduced(arch)
    params, _ = M.init(cfg, jax.random.PRNGKey(1))
    b, s = 2, 33
    batch = _batch(cfg, b, s, jax.random.PRNGKey(2))
    full = M.train_logits(cfg, params, batch)[:, -1]
    pfb = {k: (v[:, :s - 1] if k == "tokens" else
               (v[..., :s - 1] if k == "positions" else v))
           for k, v in batch.items() if k not in ("labels", "pixel_embeds")}
    _, cache = M.prefill(cfg, params, pfb, cache_len=s)
    enc_out = (M.encode(cfg, params, batch["enc_embeds"])
               if cfg.encoder_layers else None)
    lg, _ = M.serve_step(cfg, params, cache, batch["tokens"][:, s - 1:s],
                         jnp.int32(s - 1), enc_out=enc_out)
    rel = float(jnp.max(jnp.abs(full - lg[:, 0]))) / float(jnp.max(jnp.abs(full)))
    assert rel < 2e-2, rel


def test_moe_decode_matches_forward_nodrop():
    """MoE decode equals full forward when capacity dropping is disabled
    (capacity drops legitimately differ between train and serve schedules)."""
    cfg = dataclasses.replace(C.get_reduced("mixtral_8x7b"), capacity_factor=8.0)
    params, _ = M.init(cfg, jax.random.PRNGKey(1))
    b, s = 2, 33
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full = M.train_logits(cfg, params, {"tokens": toks})[:, -1]
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :s - 1]}, cache_len=s)
    lg, _ = M.serve_step(cfg, params, cache, toks[:, s - 1:s], jnp.int32(s - 1))
    rel = float(jnp.max(jnp.abs(full - lg[:, 0]))) / float(jnp.max(jnp.abs(full)))
    assert rel < 2e-2, rel


@pytest.mark.parametrize("arch", ["mixtral_8x7b", "grok1_314b"])
def test_moe_index_dispatch_matches_einsum(arch):
    """The gather/scatter MoE dispatch must reproduce the GShard one-hot
    einsum path exactly (same capacity-drop rule), values and gradients."""
    cfg_e = dataclasses.replace(C.get_reduced(arch), moe_dispatch="einsum")
    cfg_i = dataclasses.replace(cfg_e, moe_dispatch="index")
    params, _ = M.init(cfg_e, jax.random.PRNGKey(0))
    batch = _batch(cfg_e, 2, 64)

    le, ge = jax.value_and_grad(lambda p: M.loss_fn(cfg_e, p, batch)[0])(params)
    li, gi = jax.value_and_grad(lambda p: M.loss_fn(cfg_i, p, batch)[0])(params)
    assert abs(float(le) - float(li)) < 2e-4, (float(le), float(li))
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gi)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_sliding_window_cache_rolls():
    """Decoding past the window must roll the cache, matching full forward."""
    cfg = C.get_reduced("mixtral_8x7b")          # window=32 reduced
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    b, s = 1, 40                                  # prompt shorter than window
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s + 8), 0, cfg.vocab)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :s]}, cache_len=s + 8)
    # decode 8 steps past the 32-token window
    for i in range(8):
        lg, cache = M.serve_step(cfg, params, cache, toks[:, s + i:s + i + 1],
                                 jnp.int32(s + i))
    full = M.train_logits(cfg, params, {"tokens": toks})[:, -1]
    rel = float(jnp.max(jnp.abs(full - lg[:, 0]))) / float(jnp.max(jnp.abs(full)))
    assert rel < 2e-2, rel


def test_photonic_mac_model_trains():
    """QAT path: a tiny model with photonic-MAC numerics still reduces loss."""
    cfg = dataclasses.replace(C.get_reduced("yi_6b"), use_photonic_mac=True,
                              photonic_bits=8)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 64)

    @jax.jit
    def step(p, lr=5e-2):
        (loss, _), g = jax.value_and_grad(
            lambda q: M.loss_fn(cfg, q, batch), has_aux=True)(p)
        return loss, jax.tree.map(lambda a, b: a - lr * b, p, g)

    losses = []
    for _ in range(8):
        loss, params = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_photonic_bits_ablation_monotone():
    """Lower MR resolution (fewer bits) => larger quantization distortion of
    the logits (2.5D-CrossLight precision/energy trade-off)."""
    base = C.get_reduced("yi_6b")
    params, _ = M.init(base, jax.random.PRNGKey(0))
    batch = _batch(base, 2, 64)
    exact = M.train_logits(base, params, batch)
    errs = []
    for bits in (8, 4, 2):
        cfg = dataclasses.replace(base, use_photonic_mac=True, photonic_bits=bits)
        q = M.train_logits(cfg, params, batch)
        errs.append(float(jnp.mean(jnp.abs(q - exact))))
    assert errs[0] < errs[1] < errs[2], errs


def test_stage_layout_counts():
    """Stage decomposition covers exactly n_layers for every arch."""
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        total = sum(rep * len(kinds) for rep, kinds in M.stages(cfg))
        assert total == cfg.n_layers, (arch, total, cfg.n_layers)

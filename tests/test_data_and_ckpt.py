"""Token-file data source (mmap corpus) and async checkpointing."""

import concurrent.futures
import time

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint import store
from repro.checkpoint.async_store import AsyncCheckpointer
from repro.data.filesource import TokenFileSource
from repro.data.pipeline import DataConfig

CFG = C.get_reduced("yi_6b")


@pytest.fixture
def corpus(tmp_path):
    path = tmp_path / "corpus.bin"
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab, size=100_000, dtype=np.uint16)
    toks.tofile(path)
    return path


def test_tokenfile_shapes_and_determinism(corpus):
    d = DataConfig(global_batch=4, seq_len=64)
    src = TokenFileSource(CFG, d, corpus)
    b1 = src.batch_at(3)
    b2 = src.batch_at(3)
    assert b1["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_tokenfile_host_sharding_disjoint(corpus):
    d = DataConfig(global_batch=4, seq_len=32)
    h0 = TokenFileSource(CFG, d, corpus, host_index=0, host_count=2)
    h1 = TokenFileSource(CFG, d, corpus, host_index=1, host_count=2)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape == (2, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # the union matches the single-host global batch
    full = TokenFileSource(CFG, d, corpus).batch_at(0)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), full["tokens"])


def test_tokenfile_vocab_clamped(corpus):
    d = DataConfig(global_batch=2, seq_len=16)
    src = TokenFileSource(CFG, d, corpus)
    b = src.batch_at(0)
    assert int(b["tokens"].max()) < CFG.vocab


def test_async_checkpoint_roundtrip(tmp_path):
    tree = {"w": jax.numpy.arange(100, dtype=jax.numpy.float32),
            "b": jax.numpy.ones((7,))}
    ck = AsyncCheckpointer(tmp_path, keep=2)
    futs = [ck.save(s, jax.tree.map(lambda x: x + s, tree)) for s in (1, 2, 3)]
    ck.wait()
    assert all(isinstance(f, concurrent.futures.Future) and f.done()
               for f in futs)
    assert store.latest_step(tmp_path) == 3
    restored = store.restore(tmp_path, 3, tree)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(100, dtype=np.float32) + 3)
    # retention respected
    kept = [d.name for d in tmp_path.iterdir() if d.name.startswith("step_")]
    assert len(kept) <= 2
    ck.close()


def test_async_checkpoint_snapshot_isolation(tmp_path):
    """Mutating (donating) the state right after save() must not corrupt the
    written checkpoint — the host snapshot happens synchronously."""
    x = jax.numpy.zeros((1000,))
    ck = AsyncCheckpointer(tmp_path)
    ck.save(1, {"x": x})
    x = x + 999.0   # "donated"/reused immediately
    ck.wait()
    restored = store.restore(tmp_path, 1, {"x": x})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.zeros(1000))
    ck.close()

"""Photonic fault-injection layer: columnar degradation semantics, fabric
degrade/replan, Monte-Carlo availability, and the trainer/serving
fault-epoch hooks (inject at step N -> replan -> continue, or hard-fail
when nothing survives)."""

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.core import (
    FabricUnusableError,
    FaultModel,
    FaultScenario,
    HEALTHY,
    Traffic,
    availability_search,
    degrade,
    evaluate_degraded,
    faulted_columns_fn,
    get_fabric,
    overlapped_step_s,
    plan_collective_channels,
)
from repro.core.sweep import ChunkReducer, sweep, sweep_chunked
from repro.data.pipeline import DataConfig
from repro.models import model as M
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serve.engine import ContinuousBatcher

TRAFFIC = Traffic(bytes_read=1 << 30, bytes_written=1 << 28, n_transfers=64)
ALL_TOPOLOGIES = ("trine", "tree", "spacx", "sprint", "elec")

MODEL = FaultModel(p_lambda=0.15, p_bank=0.12, p_gateway=0.05, wpe_loss=0.2,
                   drift_sigma_db=0.5, tuning_sigma=0.3)


# ---------------------------------------------------------------------------
# columnar degradation semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
def test_healthy_scenario_is_identity(topo):
    got = evaluate_degraded(TRAFFIC, HEALTHY, topo)
    ref = sweep(TRAFFIC, topologies=(topo,)).metrics
    for key in ("latency_s", "energy_j", "power_w", "energy_per_bit_j"):
        np.testing.assert_allclose(got[key], ref[key], rtol=1e-6)


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES)
def test_metrics_monotone_in_severity(topo):
    """Latency and EDP never improve as every fault rate scales up (raw
    power is excluded by design: dead networks stop burning dynamic power)."""
    prev = None
    for s in (0.0, 0.25, 0.5, 1.0, 2.0, 4.0):
        m = evaluate_degraded(TRAFFIC, MODEL.scale(s).expected(), topo)
        lat, edp = float(m["latency_s"][0]), float(
            m["latency_s"][0] * m["energy_j"][0])
        if prev is not None:
            assert lat >= prev[0] * (1 - 1e-9), (topo, s)
            assert edp >= prev[1] * (1 - 1e-9), (topo, s)
        prev = (lat, edp)


def test_single_bank_design_dies_multi_bank_degrades():
    """The redundancy argument, quantitatively: one dead laser bank kills
    Tree (1 bank) outright but costs 8-bank TRINE only a 1/8 slice."""
    one_bank = FaultScenario(failed_laser_banks=1.0)
    assert np.isinf(evaluate_degraded(TRAFFIC, one_bank, "tree")["latency_s"][0])
    h = evaluate_degraded(TRAFFIC, HEALTHY, "trine")
    d = evaluate_degraded(TRAFFIC, one_bank, "trine")
    assert np.isfinite(d["latency_s"][0])
    # serialization term scales by 8/7; the fixed per-transfer term dilutes it
    assert 1.0 < d["latency_s"][0] / h["latency_s"][0] <= 8.0 / 7.0 + 1e-9


def test_trine_gateway_blast_radius_is_a_subnetwork():
    """A dead gateway severs TRINE's SWMR subnetwork behind it — the same
    bandwidth hit as a dead bank — while bus designs only lose 1/G ports."""
    one_gw = FaultScenario(failed_gateways=1.0)
    one_bank = FaultScenario(failed_laser_banks=1.0)
    trine_gw = evaluate_degraded(TRAFFIC, one_gw, "trine")
    trine_bank = evaluate_degraded(TRAFFIC, one_bank, "trine")
    np.testing.assert_allclose(trine_gw["latency_s"], trine_bank["latency_s"],
                               rtol=1e-9)
    h = float(evaluate_degraded(TRAFFIC, HEALTHY, "spacx")["latency_s"][0])
    d = float(evaluate_degraded(TRAFFIC, one_gw, "spacx")["latency_s"][0])
    assert h < d <= h * 32.0 / 31.0 * (1 + 1e-9)  # 1-of-32-ports hit only


def test_dead_hardware_does_not_lower_loss_or_power_terms():
    """Dead rings stay on the waveguide: loss-driven laser power and
    trimming never DROP under wavelength faults."""
    h = evaluate_degraded(TRAFFIC, HEALTHY, "sprint")
    d = evaluate_degraded(TRAFFIC, FaultScenario(dead_lambda_frac=0.5),
                          "sprint")
    assert d["trimming_power_w"][0] >= h["trimming_power_w"][0] * (1 - 1e-9)
    assert d["latency_s"][0] > h["latency_s"][0]


def test_batched_scenarios_broadcast():
    sc = MODEL.sample(16, rng=0)
    m = evaluate_degraded(TRAFFIC, sc, "trine")
    assert m["latency_s"].shape == (16, 1)
    assert np.all(np.isfinite(m["energy_per_bit_j"])
                  | np.isinf(m["energy_per_bit_j"]))


def test_expected_scenario_scales_with_model():
    e = MODEL.scale(0.0).expected()
    assert e.is_healthy() or (e.failed_laser_banks == 0
                              and e.dead_lambda_frac == 0)
    e2 = MODEL.scale(2.0).expected()
    assert e2.failed_laser_banks > MODEL.expected().failed_laser_banks


# ---------------------------------------------------------------------------
# sweep/search composition
# ---------------------------------------------------------------------------


class _Collect(ChunkReducer):
    def init(self, spec):
        return []

    def step(self, carry, chunk):
        carry.append({k: np.array(v) for k, v in chunk.metrics.items()})
        return carry

    def finish(self, carry, spec):
        return {k: np.concatenate([c[k] for c in carry], axis=-1)
                for k in carry[0]}


def test_faulted_columns_fn_healthy_matches_plain_sweep():
    axes = dict(n_lambda=(4.0, 8.0), mem_bw_bytes_per_s=(50e9, 100e9))
    plain = sweep_chunked(TRAFFIC, _Collect(), topologies=ALL_TOPOLOGIES,
                          chunk_size=7, **axes)
    faulted = sweep_chunked(TRAFFIC, _Collect(), topologies=ALL_TOPOLOGIES,
                            chunk_size=7,
                            columns_fn=faulted_columns_fn(HEALTHY), **axes)
    for k in plain:
        np.testing.assert_allclose(faulted[k], plain[k], rtol=1e-7)


def test_availability_search_budget_extremes():
    scenarios = MODEL.sample(8, rng=3)
    kw = dict(topologies=("trine", "tree"), chunk_size=16,
              n_lambda=(4.0, 8.0), mem_bw_bytes_per_s=(50e9, 100e9))
    lenient = availability_search(TRAFFIC, scenarios, epb_budget_j=1e3, **kw)
    strict = availability_search(TRAFFIC, scenarios, epb_budget_j=0.0, **kw)
    assert lenient["n"] == 8 and lenient["n_scenarios"] == 8
    # huge budget: availability == P(design survives at all, finite EPB);
    # tree points sit well below 1.0 (single bank), trine points at 1.0
    a = lenient["availability"]
    assert np.all((0.0 <= a) & (a <= 1.0))
    assert a.max() == 1.0 and a.min() < 1.0
    assert np.all(strict["availability"] == 0.0)
    assert np.all(a >= strict["availability"])
    assert strict["best_survivable"] is None
    assert lenient["best_survivable"] is not None
    assert lenient["best_survivable"]["config"]["topology"] in ("trine",
                                                                "tree")


def test_pareto_search_accepts_columns_fn():
    from repro.core.search import pareto_search
    scenario = MODEL.expected()
    front = pareto_search(TRAFFIC, topologies=("trine", "tree"),
                          chunk_size=16, n_lambda=(4.0, 8.0),
                          columns_fn=faulted_columns_fn(scenario))
    assert len(front.indices) >= 1


# ---------------------------------------------------------------------------
# fabric degrade + channel replanning
# ---------------------------------------------------------------------------


def test_degrade_healthy_is_identity():
    fb = get_fabric("trine_siph")
    fh = degrade(fb, HEALTHY)
    assert np.isclose(fh.cross_pod_bw_bytes_per_s,
                      fb.cross_pod_bw_bytes_per_s, rtol=1e-5)
    assert np.isclose(fh.energy_per_bit_j, fb.energy_per_bit_j, rtol=1e-3)


def test_degrade_moves_link_numbers_the_right_way():
    fb = get_fabric("trine_siph")
    fd = degrade(fb, MODEL.expected())
    assert fd.cross_pod_bw_bytes_per_s < fb.cross_pod_bw_bytes_per_s
    assert fd.energy_per_bit_j > fb.energy_per_bit_j
    assert fd.name == "trine_siph|expected"
    assert fd.source.get("degraded") == 1.0


def test_degrade_metallic_only_loses_ports():
    fb = get_fabric("metallic_ici")
    sc = FaultScenario(failed_gateways=8.0, dead_lambda_frac=0.9,
                       failed_laser_banks=4.0)
    fd = degrade(fb, sc)  # photonic knobs are no-ops on metallic links
    np.testing.assert_allclose(fd.cross_pod_bw_bytes_per_s,
                               fb.cross_pod_bw_bytes_per_s * 24 / 32)


def test_degrade_rejects_batched_scenarios():
    with pytest.raises(ValueError, match="scalar scenario"):
        degrade("trine_siph", MODEL.sample(4, rng=0))


def test_dead_fabric_hard_fails_channel_planning():
    dead = degrade("tree_siph", FaultScenario(failed_laser_banks=1.0))
    assert dead.cross_pod_bw_bytes_per_s == 0.0
    with pytest.raises(FabricUnusableError):
        plan_collective_channels(1 << 30, 0.05, fabric=dead)
    assert overlapped_step_s(0.05, 1 << 30, dead, 4) == float("inf")


def test_replanning_recovers_at_least_naive_throughput():
    fb = get_fabric("trine_siph")
    fbd = degrade(fb, MODEL.scale(2.0).expected())
    ch0 = plan_collective_channels(2 << 30, 0.05, fabric=fb, max_channels=64)
    ch1 = plan_collective_channels(2 << 30, 0.05, fabric=fbd, max_channels=64)
    assert ch1 >= ch0
    naive = overlapped_step_s(0.05, 2 << 30, fbd, ch0)
    replanned = overlapped_step_s(0.05, 2 << 30, fbd, ch1)
    assert replanned <= naive * (1 + 1e-12)


# ---------------------------------------------------------------------------
# trainer / serving fault-epoch hooks
# ---------------------------------------------------------------------------

CFG = C.get_reduced("yi_6b")
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=16)
DATA = DataConfig(global_batch=2, seq_len=64)


def _trainer(tmp, fabric=None, resume=True):
    return Trainer(CFG, OPT, DATA,
                   TrainerConfig(ckpt_dir=str(tmp), ckpt_every=2,
                                 log_every=1000),
                   resume=resume, fabric=fabric)


def test_trainer_fault_epoch_loss_continuity(tmp_path):
    """Inject a fault mid-run: the fabric degrades, the collective replans,
    and the LOSS TRAJECTORY is untouched (the fault model changes the
    modeled network time, never the numerics)."""
    ref = _trainer(tmp_path / "ref", resume=False)
    ref.run(6, quiet=True)

    tr = _trainer(tmp_path / "fault", fabric="trine_siph", resume=False)
    net_s_healthy = tr.net_s
    out = tr.run(6, quiet=True, fault_at=4,
                 fault_scenario=MODEL.scale(2.0).expected())
    assert [h["step"] for h in tr.history] == [1, 2, 3, 4, 5, 6]
    np.testing.assert_allclose([h["loss"] for h in tr.history],
                               [h["loss"] for h in ref.history], rtol=1e-6)
    # modeled network time rises at the fault epoch and never recovers
    assert tr.history[2]["net_s"] == net_s_healthy
    assert tr.history[3]["net_s"] > net_s_healthy
    assert out["fabric"].endswith("|expected")
    assert out["collective_channels"] >= 1


def test_trainer_hard_fails_on_unusable_fabric(tmp_path):
    tr = _trainer(tmp_path, fabric="tree_siph", resume=False)
    with pytest.raises(FabricUnusableError):
        tr.run(4, quiet=True, fault_at=2,
               fault_scenario=FaultScenario(failed_laser_banks=1.0))


def test_serving_fault_epoch_token_parity():
    """The serving fault hook models throughput only: tokens match a
    fabric-less engine bit-for-bit while net_stats records the fault."""
    params, _ = M.init(CFG, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = [list(np.asarray(
        jax.random.randint(jax.random.fold_in(key, i), (l,), 2, CFG.vocab)))
        for i, l in enumerate((5, 7))]

    ref = ContinuousBatcher(CFG, params, n_slots=2, max_len=64)
    for p in prompts:
        ref.submit(p, 4)
    ref_out = [r.out for r in sorted(ref.run(), key=lambda r: r.rid)]
    assert ref.net_stats["modeled_net_s"] == 0.0  # no fabric, no model

    eng = ContinuousBatcher(CFG, params, n_slots=2, max_len=64,
                            fabric="trine_siph")
    for p in prompts:
        eng.submit(p, 4)
    out = [r.out for r in sorted(
        eng.run(fault_at_iter=2,
                fault_scenario=MODEL.scale(2.0).expected()),
        key=lambda r: r.rid)]
    assert out == ref_out
    assert eng.net_stats["fault_iter"] == 2
    assert eng.net_stats["replans"] == 2  # init plan + fault replan
    assert eng.net_stats["decode_iters"] >= 4
    assert eng.net_stats["modeled_net_s"] > 0.0
    assert eng.fabric.name.endswith("|expected")


def test_serving_hard_fails_on_unusable_fabric():
    params, _ = M.init(CFG, jax.random.PRNGKey(0))
    eng = ContinuousBatcher(CFG, params, n_slots=2, max_len=64,
                            fabric="tree_siph")
    eng.submit([3, 4, 5], 4)
    with pytest.raises(FabricUnusableError):
        eng.run(fault_at_iter=1,
                fault_scenario=FaultScenario(failed_laser_banks=1.0))

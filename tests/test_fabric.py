"""Fabric (core.fabric) + the Layer-B paths it threads through: roofline
back-compat (default fabric byte-identical to the old constants), preset /
from_config / frontier constructors, the channel planner's fabric parameter,
the subnetwork planner's round modes, and the per-chunk int8 quantizer +
error-feedback residual fix in parallel.collectives.

Multi-device collective kernels are covered in tests/test_distributed.py
(subprocess, 8 devices); everything here runs on the single-device main
process.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChipletSpec,
    DEFAULT_FABRIC,
    FABRIC_PRESETS,
    Fabric,
    NetworkParams,
    choose_subnetworks,
    codesign_pareto,
    fabrics_from_front,
    get_fabric,
    metallic_ici,
    plan_collective_channels,
    trine_network,
)
from repro.core.planner import choose_subnetworks_arr
from repro.core.search import frontier_configs
from repro.core.workloads import CNN_WORKLOADS
from repro.launch import hlo_analysis as H
from repro.parallel.collectives import (
    _dequantize_int8,
    _quantize_int8,
    compressed_all_reduce,
)


def _stats(flops=1e12, coll=5e8, n_coll=3):
    return H.HloStats(
        dot_flops=flops, dot_bytes=1e9, op_result_bytes=0.0,
        collective_bytes=coll, collective_op_bytes={},
        collective_op_counts={"all-reduce": n_coll}, max_trip=2,
        collective_bytes_raw=coll)


# ---------------------------------------------------------------------------
# roofline back-compat + fabric threading
# ---------------------------------------------------------------------------


def test_default_fabric_byte_identical_roofline():
    """fabric=None must price exactly like the historical constants (the
    metallic preset has link_latency 0, so no new term appears)."""
    stats = _stats()
    rf = H.roofline(stats, {}, 9e11, io_bytes=1e8)
    assert rf.compute_s == rf.flops / H.PEAK_FLOPS
    assert rf.memory_s == rf.hbm_bytes / H.HBM_BW
    assert rf.collective_s == rf.collective_bytes / H.ICI_BW
    assert rf.fabric == "metallic_ici"
    # naming the default explicitly changes nothing
    rf2 = H.roofline(stats, {}, 9e11, io_bytes=1e8, fabric="metallic_ici")
    assert rf2 == rf


def test_roofline_fabric_moves_only_collective_term():
    stats = _stats()
    base = H.roofline(stats, {}, 9e11, io_bytes=1e8)
    ph = H.roofline(stats, {}, 9e11, io_bytes=1e8, fabric="trine_siph")
    assert ph.compute_s == base.compute_s
    assert ph.memory_s == base.memory_s
    assert ph.collective_s < base.collective_s
    assert ph.fabric == "trine_siph"
    fb = get_fabric("trine_siph")
    want = stats.collective_bytes / fb.cross_pod_bw_bytes_per_s \
        + 3 * fb.link_latency_s
    assert ph.collective_s == pytest.approx(want, rel=1e-12)


def test_collective_s_strictly_decreases_with_cross_pod_bw():
    bws = [3e9, 12e9, 50e9, 96e9, 384e9]
    times = [Fabric("f", bw, bw, link_latency_s=40e-9)
             .collective_s(1e9, n_collectives=10.0) for bw in bws]
    assert all(a > b for a, b in zip(times, times[1:]))


def test_fabric_term_helpers():
    fb = Fabric("f", 10e9, 20e9, hbm_bw_bytes_per_s=800e9,
                peak_flops=100e12, link_latency_s=1e-7,
                energy_per_bit_j=1e-12)
    assert fb.compute_s(1e12) == pytest.approx(0.01)
    assert fb.memory_s(8e9) == pytest.approx(0.01)
    assert fb.collective_s(1e9, 5) == pytest.approx(0.1 + 5e-7)
    assert fb.collective_energy_j(1e9) == pytest.approx(8e-3)


# ---------------------------------------------------------------------------
# constructors: presets, config dicts, network models, frontiers
# ---------------------------------------------------------------------------


def test_presets_bracket_the_metallic_baseline():
    fabs = {n: FABRIC_PRESETS[n]() for n in FABRIC_PRESETS}
    cross = {n: f.cross_pod_bw_bytes_per_s for n, f in fabs.items()}
    assert cross["metallic_ici"] == 50e9
    assert cross["trine_siph"] > cross["metallic_ici"]      # ~96 GB/s
    assert cross["tree_siph"] < cross["metallic_ici"]       # ~12 GB/s
    assert cross["elec_mesh"] < cross["tree_siph"]
    for n, f in fabs.items():
        assert f.name == n
        assert f.intra_pod_bw_bytes_per_s >= f.cross_pod_bw_bytes_per_s
        assert f.peak_flops == H.PEAK_FLOPS
        assert f.energy_per_bit_j > 0
        assert f.link_latency_s >= 0


def test_get_fabric_resolution():
    assert get_fabric(None) is DEFAULT_FABRIC
    fb = metallic_ici()
    assert get_fabric(fb) is fb
    assert get_fabric("tree_siph").name == "tree_siph"
    with pytest.raises(KeyError, match="unknown fabric preset"):
        get_fabric("copper_dream")
    with pytest.raises(TypeError):
        get_fabric(42)


def test_from_network_model_matches_topology_numbers():
    net = trine_network(NetworkParams())
    fb = Fabric.from_network_model(net, name="t")
    assert fb.cross_pod_bw_bytes_per_s == pytest.approx(
        net.effective_bw_bps / 8.0)
    assert fb.intra_pod_bw_bytes_per_s >= fb.cross_pod_bw_bytes_per_s
    assert fb.link_latency_s == net.per_transfer_s
    assert fb.energy_per_bit_j > 0


def test_from_config_applies_axis_overrides():
    fb = Fabric.from_config({"topology": "trine", "n_lambda": 16.0,
                             "mem_bw_bytes_per_s": 200e9,
                             "mix": 1, "chiplets": ()})   # mix keys ignored
    base = Fabric.from_config({"topology": "trine"})
    assert fb.cross_pod_bw_bytes_per_s > base.cross_pod_bw_bytes_per_s
    assert fb.source["topology"] == "trine"
    assert fb.source["n_lambda"] == 16.0
    with pytest.raises(KeyError, match="unknown config column"):
        Fabric.from_config({"topology": "trine", "warp_factor": 9.0})
    with pytest.raises(KeyError, match="unknown topology"):
        Fabric.from_config({"topology": "subspace"})


@pytest.fixture(scope="module")
def small_front():
    wl = CNN_WORKLOADS["ResNet18"]()
    mixes = [[ChipletSpec(512, 32)], [ChipletSpec(256, 64)]]
    front, spec = codesign_pareto(
        wl, mixes, topologies=("trine",), chunk_size=8,
        n_lambda=(4.0, 8.0), mem_bw_bytes_per_s=(50e9, 100e9))
    return front, spec, mixes


def test_fabrics_from_front_dedup_and_traceability(small_front):
    front, spec, mixes = small_front
    fabs = fabrics_from_front(front, spec, mixes=mixes)
    assert fabs, "frontier produced no fabrics"
    # traceable: every fabric names a flat index that is ON the EDP front
    idx = {int(i) for i in front.indices}
    for f in fabs:
        topo, at = f.name.removeprefix("pareto:").split("@")
        assert topo == "trine"
        assert int(at) in idx
    # deduped: same network config (mix excluded) never appears twice
    keys = [tuple(sorted(f.source.items())) for f in fabs]
    assert len(keys) == len(set(keys))
    # two mixes over the same network grid collapse to one fabric each
    assert len(fabs) <= spec.n
    assert len(fabrics_from_front(front, spec, mixes=mixes,
                                  max_fabrics=1)) == 1


def test_frontier_configs_mix_aware(small_front):
    front, spec, mixes = small_front
    cfgs = frontier_configs(front, spec, mixes)
    assert len(cfgs) == len(front.indices)
    assert all("chiplets" in c and "topology" in c for c in cfgs)
    # without mixes: plain network-grid configs
    plain_front, plain_spec = front, spec
    if all(int(i) < spec.n for i in front.indices):
        plain = frontier_configs(plain_front, plain_spec)
        assert all("chiplets" not in c for c in plain)


# ---------------------------------------------------------------------------
# planner: fabric-aware channel planning + K round modes
# ---------------------------------------------------------------------------


def test_plan_collective_channels_fabric_parity():
    args = dict(collective_bytes=2e9, overlap_window_s=10e-3,
                max_channels=64)
    by_bw = plan_collective_channels(link_bw_bytes_per_s=50e9, **args)
    by_name = plan_collective_channels(fabric="metallic_ici", **args)
    by_obj = plan_collective_channels(fabric=metallic_ici(), **args)
    assert by_bw == by_name == by_obj == 4
    # a slower fabric needs more parallelism to fit the same window
    assert plan_collective_channels(fabric="tree_siph", **args) > by_bw
    # the fabric under evaluation wins over a stale explicit bandwidth
    assert plan_collective_channels(link_bw_bytes_per_s=1e30,
                                    fabric="tree_siph", **args) > by_bw
    with pytest.raises(ValueError, match="link_bw_bytes_per_s or fabric"):
        plan_collective_channels(2e9, 10e-3)


def test_choose_subnetworks_round_modes():
    p = NetworkParams()
    # paper: raw K = 9 -> nearest power of two = 8 (the default preserves
    # the paper's published choice)
    assert choose_subnetworks(p) == 8
    assert choose_subnetworks(p, round_mode="paper") == 8
    # cover: next power of two up = 16, never below the memory bandwidth
    assert choose_subnetworks(p, round_mode="cover") == 16
    with pytest.raises(ValueError, match="round_mode"):
        choose_subnetworks(p, round_mode="banker")


def test_choose_subnetworks_cover_never_underprovisions():
    rng = np.random.default_rng(0)
    n_lambda = rng.integers(1, 32, 64).astype(float)
    rate = rng.uniform(4e9, 16e9, 64)
    mem = rng.uniform(10e9, 400e9, 64)
    n_gw = np.full(64, 1024.0)  # large so the gateway clamp never bites
    k_cover = choose_subnetworks_arr(n_lambda, rate, 1.0, mem, n_gw,
                                     round_mode="cover")
    k_paper = choose_subnetworks_arr(n_lambda, rate, 1.0, mem, n_gw,
                                     round_mode="paper")
    wg = n_lambda * rate
    assert np.all(k_cover * wg >= mem * 8.0)
    assert np.all(k_cover >= k_paper)
    # paper mode does round down sometimes (that is the documented behavior)
    assert np.any(k_paper * wg < mem * 8.0)


# ---------------------------------------------------------------------------
# collectives: per-chunk int8 scales + error-feedback residual hygiene
# ---------------------------------------------------------------------------


def _rel_err(x, chunk_elems):
    q, s = _quantize_int8(x, chunk_elems)
    deq = _dequantize_int8(q, s, x.shape[0])
    return float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x))


def test_per_chunk_quantize_matches_global_on_smooth_tensors():
    x = jnp.sin(jnp.linspace(0.0, 20.0, 4096)) * 3.0
    err_global = _rel_err(x, None)
    err_chunked = _rel_err(x, 256)
    assert err_global < 0.01
    assert err_chunked <= err_global * 1.5 + 1e-6


def test_per_chunk_quantize_wins_on_outlier_heavy_tensors():
    """One huge spike must not flatten every other chunk's resolution — the
    docstring's promise the old single-global-scale implementation broke."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,)) * 1e-3
    x = x.at[17].set(100.0)

    def small_part_err(chunk_elems):
        q, s = _quantize_int8(x, chunk_elems)
        deq = _dequantize_int8(q, s, x.shape[0])
        d, r = (deq[256:], x[256:])  # everything outside the spike's chunk
        return float(jnp.linalg.norm(d - r) / jnp.linalg.norm(r))

    err_global = small_part_err(None)
    err_chunked = small_part_err(256)
    # one global scale of ~100/127 rounds every ~1e-3 element to zero
    assert err_global > 0.99
    assert err_chunked < 0.01
    # per-chunk scales really are per-chunk (non-constant across blocks)
    _, scales = _quantize_int8(x, 256)
    assert scales.shape == (16,)
    assert float(scales.max()) > 10 * float(scales.min())


def test_quantize_chunk_handles_padding_and_clamp():
    x = jnp.arange(7.0) - 3.0          # length not divisible by the chunk
    q, s = _quantize_int8(x, 4)
    assert q.shape == (2, 4) and s.shape == (2,)
    deq = _dequantize_int8(q, s, 7)
    assert deq.shape == (7,)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x), atol=0.05)
    # chunk_elems larger than the tensor falls back to one global scale
    q1, s1 = _quantize_int8(x, 10_000)
    assert s1.shape == (1,)


def test_compressed_all_reduce_no_pod_drains_residual():
    """EF hygiene on meshes without a 'pod' axis: the pending residual must
    be folded into the payload and come back zeroed, not returned stale
    (the leak this PR fixes — a stale residual is re-applied forever)."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jnp.asarray(np.linspace(-1.0, 1.0, 64), jnp.float32)
    res = jnp.full((64,), 0.25, jnp.float32)
    out, new_res = compressed_all_reduce(x, mesh, residual=res)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x + res),
                               rtol=1e-6)
    assert float(jnp.abs(new_res).max()) == 0.0
    # and with no residual passed, it is the plain all-reduce
    out2, res2 = compressed_all_reduce(x, mesh)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(x), rtol=1e-6)
    assert float(jnp.abs(res2).max()) == 0.0

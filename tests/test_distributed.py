"""Multi-device tests (8 fake CPU devices in a subprocess — the main pytest
process must keep the default 1-device view per the dry-run contract).

Covers: TRINE hierarchical + compressed collectives (correctness and
cross-pod byte accounting), sharding rules over a (pod, data, model) mesh,
activation constraints, and the HLO analyzer against real compiled programs.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_test_mesh
    from repro.parallel import collectives as CC
    from repro.parallel import sharding as S
    from repro.parallel import actx
    from repro import configs as C
    from repro.models import model as M
    from repro.launch import hlo_analysis as H

    mesh = make_test_mesh(data=2, model=2, pod=2)

    # ---- TRINE hierarchical all-reduce == flat all-reduce (numerics) ----
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 33))
    flat = CC.flat_all_reduce(x, mesh)
    trine = CC.trine_all_reduce(x, mesh)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(trine), rtol=1e-6)
    # grad sync reduces over pod x data = 4 participants (model axis is TP)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(x) * 4, rtol=1e-6)
    print("OK trine_all_reduce")

    # ---- compressed all-reduce: bounded error + error feedback ----
    g = jax.random.normal(jax.random.PRNGKey(1), (256,))
    exact = np.asarray(CC.flat_all_reduce(g, mesh))
    out, res = CC.compressed_all_reduce(g, mesh)
    err = np.max(np.abs(np.asarray(out) - exact))
    scale = np.max(np.abs(exact)) / 127
    assert err <= 8 * scale + 1e-5, (err, scale)
    # error feedback: feeding residual back must reduce accumulated bias
    out2, res2 = CC.compressed_all_reduce(g, mesh, residual=res)
    two_step_exact = 2 * exact
    ef = np.max(np.abs(np.asarray(out) + np.asarray(out2) - two_step_exact))
    no_ef = np.max(np.abs(2 * np.asarray(out) - two_step_exact))
    assert ef <= no_ef + 1e-6, (ef, no_ef)
    print("OK compressed_all_reduce")

    # ---- cross-pod byte accounting on PRODUCTION geometry (2,16,16): the
    # hierarchical schedule's advantage scales with the data-axis size ----
    class _G:  # geometry stand-in
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
    est_flat = CC.collective_bytes_estimate(10_000_000, 4, _G, "flat")
    est_trine = CC.collective_bytes_estimate(10_000_000, 4, _G, "trine")
    est_int8 = CC.collective_bytes_estimate(10_000_000, 4, _G, "trine_int8")
    assert est_trine["cross_pod_bytes"] < est_flat["cross_pod_bytes"] / 10
    assert est_int8["cross_pod_bytes"] < est_trine["cross_pod_bytes"] / 3
    print("OK byte estimates")

    # ---- byte model vs compiled HLO: the trine_int8 estimate (including
    # the residual all-gather and the f32 scale payload) must match the
    # wire bytes the analyzer reads off the ACTUAL compiled program ----
    n = 4096
    for chunk in (None, 64):
        fn = jax.jit(lambda v, r: CC.compressed_all_reduce(
            v, mesh, residual=r, chunk_elems=chunk))
        txt = fn.lower(jnp.zeros((n,), jnp.float32),
                       jnp.zeros((n,), jnp.float32)).compile().as_text()
        stats = H.analyze_hlo(txt, 8)
        est = CC.collective_bytes_estimate(n, 4, mesh, "trine_int8",
                                           chunk_elems=chunk)
        assert stats.collective_bytes_raw == est["total_bytes"], (
            chunk, stats.collective_bytes_raw, est["total_bytes"],
            stats.collective_op_bytes)
    print("OK trine_int8 bytes match compiled HLO")

    # ---- sharding rules for every arch on the 3-axis mesh ----
    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        rules = S.rules_for(cfg, mesh)
        shapes, specs = M.init_abstract(cfg)
        sh = S.enforce_divisibility(S.tree_shardings(mesh, specs, rules), shapes)
        # every sharding is valid for its leaf
        def check(s_, l_):
            for dim, ax in zip(l_.shape, list(s_.spec) + [None]*(len(l_.shape)-len(s_.spec))):
                if ax is None: continue
                axes = (ax,) if isinstance(ax, str) else ax
                n = 1
                for a in axes:
                    n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                assert dim % n == 0, (arch, l_.shape, s_.spec)
        jax.tree.map(check, sh, shapes,
                     is_leaf=lambda x: isinstance(x, NamedSharding))
    print("OK sharding rules all archs")

    # ---- tiny end-to-end sharded train step on the mesh + HLO analysis ----
    from repro.optim import adamw
    from repro.runtime.trainer import make_train_step
    cfg = C.get_reduced("yi_6b")
    opt = adamw.OptConfig()
    params, pspecs = M.init(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(opt, params)
    rules = S.rules_for(cfg, mesh)
    state_sh = S.enforce_divisibility(
        S.tree_shardings(mesh, adamw.state_specs(pspecs), rules),
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "labels": jnp.zeros((4, 64), jnp.int32)}
    batch_sh = S.train_batch_shardings(cfg, mesh, batch)
    dp = S.batch_axes(mesh, 4)
    with mesh, actx.activation_sharding(mesh, dp):
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(state_sh, batch_sh))
        lowered = step.lower(state, batch)
        compiled = lowered.compile()
    stats = H.analyze_hlo(compiled.as_text(), 8)
    assert stats.max_trip >= 2, stats.max_trip          # layer scan detected
    assert stats.dot_flops > 0
    assert stats.collective_bytes > 0                    # TP psums present
    # run one real step
    state2, metrics = compiled(jax.device_put(state, state_sh),
                               jax.device_put(batch, batch_sh))
    assert bool(jnp.isfinite(metrics["loss"]))
    print("OK sharded train step + hlo analysis")
""")


@pytest.mark.slow
def test_multidevice_suite(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    for marker in ("OK trine_all_reduce", "OK compressed_all_reduce",
                   "OK byte estimates",
                   "OK trine_int8 bytes match compiled HLO",
                   "OK sharding rules all archs",
                   "OK sharded train step + hlo analysis"):
        assert marker in r.stdout

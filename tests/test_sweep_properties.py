"""Property-style parametrized invariants of the topology/power models,
checked over sweep-engine grids (physics must hold at every grid point, not
just the paper's operating point):

  * effective_bw_bps <= aggregate_bw_bps (derating never creates bandwidth)
  * worst-path loss monotonically non-decreasing in gateway count
  * total network power positive, and increasing in n_lambda (photonic)
"""

import numpy as np
import pytest

from repro.core import Traffic
from repro.core.sweep import build_grid, network_columns, sweep

TRAFFIC = Traffic(bytes_read=1.5e8, bytes_written=5e7, n_transfers=200)

GATEWAYS = (8, 16, 24, 32, 48, 64)
LAMBDAS = (2, 4, 8, 16, 32)
PHOTONIC = ("sprint", "spacx", "tree", "trine")
ALL = PHOTONIC + ("elec",)


@pytest.mark.parametrize("topology", ALL)
def test_effective_bw_never_exceeds_aggregate(topology):
    grid = build_grid((topology,), n_gateways=GATEWAYS, n_lambda=LAMBDAS)
    nets = network_columns(grid)
    assert np.all(nets["effective_bw_bps"] <= nets["aggregate_bw_bps"] * (1 + 1e-12))
    assert np.all(nets["effective_bw_bps"] > 0)


@pytest.mark.parametrize("topology", PHOTONIC)
def test_worst_path_loss_monotone_in_gateways(topology):
    """More gateways can never shorten the worst-case optical path: buses
    accumulate ring through-loss per writer, trees add stages."""
    grid = build_grid((topology,), n_gateways=GATEWAYS)
    nets = network_columns(grid)
    loss = nets["worst_path_loss_db"].reshape(grid.shape)[0]
    assert np.all(loss > 0)
    assert np.all(np.diff(loss) >= -1e-12)


def test_bus_loss_strictly_increasing_in_gateways():
    """For the MWMR bus specifically the growth is strict — the paper's core
    argument against bus scale-out."""
    grid = build_grid(("sprint",), n_gateways=GATEWAYS)
    loss = network_columns(grid)["worst_path_loss_db"].reshape(grid.shape)[0]
    assert np.all(np.diff(loss) > 0)


@pytest.mark.parametrize("topology", PHOTONIC)
def test_power_positive_and_increasing_in_lambda(topology):
    """More lit wavelengths always cost power: laser scales with the lambda
    count, trimming with the ring count.  TRINE's subnetwork count is pinned
    (n_subnetworks=8) so the structure — not the planner's K — varies only
    in n_lambda."""
    kw = {"n_subnetworks": (8,)} if topology == "trine" else {}
    res = sweep(TRAFFIC, topologies=(topology,), n_lambda=LAMBDAS, **kw)
    power = res.metric("power_w")[0].squeeze()
    assert power.shape == (len(LAMBDAS),)
    assert np.all(power > 0)
    assert np.all(np.diff(power) > 0)


@pytest.mark.parametrize("topology", ALL)
def test_all_metrics_finite_and_positive(topology):
    res = sweep(TRAFFIC, topologies=(topology,),
                n_gateways=GATEWAYS, n_lambda=LAMBDAS)
    for key in ("power_w", "latency_s", "energy_j", "energy_per_bit_j"):
        v = res.metrics[key]
        assert np.all(np.isfinite(v)), key
        assert np.all(v > 0), key

"""Fault tolerance, checkpointing, data determinism, straggler accounting."""

import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint import store
from repro.data.pipeline import DataConfig, DeadlineMonitor, Prefetcher, SyntheticLM
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import (FailureInjected, Trainer, TrainerConfig,
                                   run_with_restarts)

CFG = C.get_reduced("yi_6b")
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=16)
DATA = DataConfig(global_batch=2, seq_len=64)


def _trainer(tmp, resume=True):
    return Trainer(CFG, OPT, DATA,
                   TrainerConfig(ckpt_dir=str(tmp), ckpt_every=2, log_every=1000),
                   resume=resume)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


def test_resume_bitwise_identical(tmp_path):
    """Crash at step 3 + restart == uninterrupted run (bitwise)."""
    a = tmp_path / "a"
    b = tmp_path / "b"

    t_straight = Trainer(CFG, OPT, DATA, TrainerConfig(
        ckpt_dir=str(a), ckpt_every=2, log_every=1000), resume=False)
    t_straight.run(6, quiet=True)

    t_crash = run_with_restarts(
        lambda: _trainer(b), total_steps=6, fail_at=(4,))

    for x, y in zip(_leaves(t_straight.state), _leaves(t_crash.state)):
        np.testing.assert_array_equal(x, y)


def test_checkpoint_atomicity_and_retention(tmp_path):
    t = _trainer(tmp_path, resume=False)
    t.run(8, quiet=True)
    assert store.latest_step(tmp_path) == 8
    kept = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step_"))
    assert len(kept) <= 3  # retention
    assert not any(d.name.endswith(".tmp") for d in tmp_path.iterdir())


def test_checkpoint_corruption_detected(tmp_path):
    t = _trainer(tmp_path, resume=False)
    t.run(2, quiet=True)
    step = store.latest_step(tmp_path)
    ck = tmp_path / f"step_{step:08d}"
    victim = next(ck.glob("leaf_*.npy"))
    victim.write_bytes(b"corrupted!" + victim.read_bytes()[10:])
    with pytest.raises(IOError, match="corruption"):
        store.restore(tmp_path, step, t.state)


def test_elastic_restore_roundtrip(tmp_path):
    """Checkpoints restore independently of the device layout that wrote them
    (full logical arrays + new shardings on load)."""
    t = _trainer(tmp_path, resume=False)
    t.run(2, quiet=True)
    step = store.latest_step(tmp_path)
    restored = store.restore(tmp_path, step, t.state, shardings=None)
    for x, y in zip(_leaves(t.state), _leaves(restored)):
        np.testing.assert_array_equal(x, y)


def test_data_step_indexed_determinism():
    src = SyntheticLM(CFG, DATA)
    b1 = src.batch_at(7)
    b2 = src.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_disjoint():
    d = DataConfig(global_batch=4, seq_len=32)
    h0 = SyntheticLM(CFG, d, host_index=0, host_count=2).batch_at(0)
    h1 = SyntheticLM(CFG, d, host_index=1, host_count=2).batch_at(0)
    assert h0["tokens"].shape == (2, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_prefetcher_yields_in_order():
    src = SyntheticLM(CFG, DATA)
    pf = Prefetcher(iter(src), depth=2)
    first = next(pf)
    np.testing.assert_array_equal(first["tokens"], src.batch_at(0)["tokens"])
    second = next(pf)
    np.testing.assert_array_equal(second["tokens"], src.batch_at(1)["tokens"])
    pf.close()


def test_straggler_deadline_accounting():
    mon = DeadlineMonitor(deadline_s=0.5)
    assert mon.admit(0.1)
    assert not mon.admit(0.9)
    assert mon.stats.steps == 2 and mon.stats.dropped == 1
    assert mon.stats.drop_rate == pytest.approx(0.5)
    assert mon.survivor_scale(16, 1) == pytest.approx(16 / 15)


def test_wire_format_training_converges(tmp_path):
    """int8 param wire (QAT straight-through) trains: loss decreases and ends
    within a modest factor of the f32 baseline on the same data."""
    from repro.parallel import wire as W
    from repro.runtime.trainer import make_train_step
    from repro.optim import adamw
    from repro.models import model as M

    cfg8 = dataclasses.replace(CFG, wire_bits=8)
    key = jax.random.PRNGKey(0)
    params, specs = M.init(CFG, key)

    src = SyntheticLM(CFG, DATA)

    def run(cfg, pw):
        step = jax.jit(make_train_step(cfg, OPT, param_wire=pw),
                       donate_argnums=(0,))
        st = adamw.init_state(OPT, jax.tree.map(jnp.copy, params))
        losses = []
        for i in range(12):
            st, m = step(st, src.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    # single-device: the sharding constraint inside wire needs a mesh, so
    # emulate the numerics-only path with a trivial 1x1 mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.parallel import sharding as S
    rules = S.rules_for(cfg8, mesh)
    pw = W.make_param_wire(cfg8, mesh, rules, specs)

    base = run(CFG, None)
    quant = run(cfg8, pw)
    assert base[-1] < base[0]
    assert quant[-1] < quant[0]            # QAT still learns
    assert quant[-1] < base[0]             # and beats the untrained loss
    assert quant[-1] < base[-1] * 1.5 + 0.5


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 over half-microbatches == one full-batch step (the CE is
    a per-token mean and microbatches are equal-sized, so mean-of-means is
    exact up to f32 reassociation)."""
    from repro.models import model as M
    from repro.optim import adamw
    from repro.runtime.trainer import make_train_step

    params, _ = M.init(CFG, jax.random.PRNGKey(0))
    src = SyntheticLM(CFG, DataConfig(global_batch=4, seq_len=64))
    batch = src.batch_at(0)

    s1 = adamw.init_state(OPT, jax.tree.map(jnp.copy, params))
    s2 = adamw.init_state(OPT, jax.tree.map(jnp.copy, params))
    step1 = jax.jit(make_train_step(CFG, OPT))
    step2 = jax.jit(make_train_step(CFG, OPT, accum_steps=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_failure_injection_raises(tmp_path):
    t = _trainer(tmp_path, resume=False)
    with pytest.raises(FailureInjected):
        t.run(6, fail_at=2, quiet=True)
    # checkpoint from before the failure exists
    assert store.latest_step(tmp_path) == 2

"""Continuous-batching engine: ragged requests through shared cache slots
must reproduce exactly the tokens of independent per-request decoding
(greedy).  Covers attention (yi-6b reduced, bucketed prefill) and the hybrid
recurrent family (zamba2 reduced, exact-length prefill)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import model as M
from repro.serve.engine import ContinuousBatcher


def _reference_decode(cfg, params, prompt, max_new, max_len):
    toks = jnp.asarray([prompt], jnp.int32)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :-1]},
                         cache_len=max_len) if len(prompt) > 1 else (None, None)
    if cache is None:
        cache, _ = M.init_cache(cfg, 1, max_len)
    out = []
    tok = jnp.asarray([[prompt[-1]]], jnp.int32)
    pos = len(prompt) - 1
    for _ in range(max_new):
        logits, cache = M.serve_step(cfg, params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["yi_6b", "zamba2_1p2b"])
def test_continuous_batching_matches_reference(arch):
    cfg = C.get_reduced(arch)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    max_len = 64

    # ragged prompts, more requests than slots -> slots churn
    lengths = [5, 9, 3, 7]
    max_news = [6, 4, 5, 3]
    prompts = [list(np.asarray(
        jax.random.randint(jax.random.fold_in(key, i), (l,), 2, cfg.vocab)))
        for i, l in enumerate(lengths)]

    eng = ContinuousBatcher(cfg, params, n_slots=2, max_len=max_len)
    reqs = [eng.submit(p, mn) for p, mn in zip(prompts, max_news)]
    finished = eng.run()
    assert len(finished) == len(reqs)
    assert all(r.done for r in reqs)

    for p, mn, r in zip(prompts, max_news, reqs):
        ref = _reference_decode(cfg, params, p, mn, max_len)
        assert r.out == ref, (p, r.out, ref)


def test_vector_position_decode_matches_scalar():
    """serve_step with a (B,) position vector == per-example scalar calls."""
    cfg = C.get_reduced("yi_6b")
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    b, s, max_len = 3, 12, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 2, cfg.vocab)
    _, cache = M.prefill(cfg, params, {"tokens": toks}, cache_len=max_len)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (b, 1), 2, cfg.vocab)

    # scalar path (all at position s)
    lg_scalar, _ = M.serve_step(cfg, params, cache, nxt, jnp.int32(s))
    # vector path with identical positions
    lg_vec, _ = M.serve_step(cfg, params, cache, nxt,
                             jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_scalar), np.asarray(lg_vec),
                               rtol=1e-5, atol=1e-5)

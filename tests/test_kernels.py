"""Per-kernel correctness: Pallas (interpret mode on CPU) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests on the
quantization (MR weight-bank) numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.photonic_mac import photonic_mac, quantize_weights
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels import ops


# ---------------------------------------------------------------------------
# photonic MAC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 512), (384, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [8, 4])
def test_photonic_mac_matches_oracle(m, k, n, dtype, bits):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n + bits))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    wq, sc = quantize_weights(w, bits=bits)
    out_k = photonic_mac(x, wq, sc, interpret=True)
    out_r = ref.photonic_mac_ref(x, wq, sc)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol * 10)


def test_photonic_mac_block_shapes():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    wq, sc = quantize_weights(w, bits=8, bk=128, bn=128)
    base = ref.photonic_mac_ref(x, wq, sc)
    for bm in (128, 256):
        out = photonic_mac(x, wq, sc, bm=bm, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(100, 128, 128),    # ragged M only
                                   (128, 200, 300),    # ragged K and N
                                   (1, 128, 50257 % 512),  # vocab-tail-ish
                                   (130, 129, 131)])   # every dim ragged
def test_photonic_mac_non_aligned_shapes(m, k, n):
    """Non-MXU-aligned shapes (vocab tails, odd hidden dims) run via the
    kernel's zero-pad + slice and match the oracle on the valid window."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    wq, sc = quantize_weights(w, bits=8)
    assert wq.shape == (k, n)
    assert sc.shape == (-(-k // 128), -(-n // 128))
    out_k = photonic_mac(x, wq, sc, interpret=True)
    out_r = ref.photonic_mac_ref(x, wq, sc)
    assert out_k.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-3)


def test_photonic_mac_padding_is_exact_on_aligned_shapes():
    """The pad+slice path must be a no-op for aligned shapes: quantizing a
    weight matrix embedded in a larger zero-padded one yields identical
    levels and scales, and the kernel output is bit-identical."""
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 256), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 256), jnp.float32)
    wq_a, sc_a = quantize_weights(w, bits=8)
    wq_b, sc_b = quantize_weights(w[:200, :250], bits=8)
    # zero padding never widens a bank's absmax: shared tiles agree exactly
    np.testing.assert_array_equal(np.asarray(sc_b[:1, :1]),
                                  np.asarray(sc_a[:1, :1]))
    np.testing.assert_array_equal(np.asarray(wq_b[:128, :128]),
                                  np.asarray(wq_a[:128, :128]))
    out_a = photonic_mac(x, wq_a, sc_a, interpret=True)
    out_b = photonic_mac(x[:100], wq_a, sc_a, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_a)[:100])


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(min_value=2, max_value=8),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_quantization_error_bound(bits, seed):
    """Per-tile symmetric quantization error is bounded by scale/2 — the MR
    amplitude-resolution guarantee the accelerator model assumes."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (128, 128), jnp.float32)
    wq, sc = quantize_weights(w, bits=bits)
    deq = ref.dequantize_ref(wq, sc)
    err = jnp.max(jnp.abs(deq - w))
    assert float(err) <= float(jnp.max(sc)) / 2 + 1e-6


def test_photonic_matmul_ste_gradients():
    """Straight-through estimator: gradient wrt w equals the unquantized
    matmul gradient."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
    g = jax.grad(lambda w_: jnp.sum(ops.photonic_matmul(x, w_, 8, False)))(w)
    g_ref = jax.grad(lambda w_: jnp.sum(x @ w_))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5)


def test_wire_quant_leaf_numerics_and_ste():
    """int8 wire leaf: dequantized weights within one quant step of the
    master (per-tensor scale for 2-D, per-layer for stacked), gradients
    straight-through (QAT identity)."""
    from repro.parallel.wire import _quant_leaf
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)
    wd = _quant_leaf(w, 8, None, jnp.float32)
    step = jnp.max(jnp.abs(w)) / 127.0
    assert float(jnp.max(jnp.abs(wd - w))) <= float(step) / 2 + 1e-6
    g = jax.grad(lambda w_: jnp.sum(_quant_leaf(w_, 8, None, jnp.float32) ** 2))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * wd), rtol=1e-5)
    # stacked (layers, K, N): scale per layer
    ws = jnp.stack([w, 100.0 * w])
    wds = _quant_leaf(ws, 8, None, jnp.float32)
    np.testing.assert_allclose(np.asarray(wds[1] / 100.0), np.asarray(wds[0]),
                               rtol=1e-5, atol=1e-6)


def test_wire_grads_close_to_master():
    """End-to-end: wire-transformed loss gradients stay close to the f32
    master gradients (bf16 tight, int8 within QAT tolerance)."""
    import dataclasses as _dc
    from repro import configs as C
    from repro.models import model as M
    from repro.parallel import wire as W
    from repro.parallel import sharding as S
    CFG = C.get_reduced("yi_6b")
    params, specs = M.init(CFG, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = S.rules_for(CFG, mesh)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, CFG.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, CFG.vocab)}
    g0 = jax.grad(lambda p: M.loss_fn(CFG, p, batch)[0])(params)
    for bits, tol in ((16, 0.05), (8, 0.25)):
        pw = W.make_param_wire(_dc.replace(CFG, wire_bits=bits), mesh, rules, specs)
        qtree = pw.quantize(params)
        g = jax.grad(lambda v: M.loss_fn(CFG, pw.graft(qtree, v), batch)[0])(
            pw.carrier(params))
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g)):
            na = float(jnp.linalg.norm(a.astype(jnp.float32)))
            nd = float(jnp.linalg.norm((a - b).astype(jnp.float32)))
            assert nd <= tol * na + 1e-6, (bits, nd, na)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,hq,hk,d", [
    (128, 128, 4, 4, 64),      # MHA
    (256, 256, 8, 2, 64),      # GQA 4:1
    (128, 256, 8, 1, 128),     # MQA, longer KV
    (512, 512, 2, 2, 32),      # long, small heads
    (128, 384, 16, 8, 64),     # GQA 2:1, 3x KV
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_matches_oracle(sq, sk, hq, hk, d, window):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk + hq + window), 3)
    q = jax.random.normal(ks[0], (2, hq, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, hk, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, hk, sk, d), jnp.float32)
    off = sk - sq
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_offset=off, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 4, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 4, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-2)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,l,p,n", [(2, 128, 16, 8), (4, 256, 32, 16),
                                      (1, 512, 64, 64), (8, 128, 8, 4),
                                      (2, 1024, 32, 32)])
def test_ssm_scan_matches_oracle(bh, l, p, n):
    ks = jax.random.split(jax.random.PRNGKey(bh * l), 4)
    x = jax.random.normal(ks[0], (bh, l, p)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (bh, l))) * 0.3 + 0.68
    b = jax.random.normal(ks[2], (bh, l, n)) * 0.3
    c = jax.random.normal(ks[3], (bh, l, n)) * 0.3
    out = ssm_scan(x, a, b, c, interpret=True)
    exp = ref.ssm_scan_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_bf16_inputs():
    """The kernel accepts the model's bf16 operands (f32 VMEM accumulation);
    must track the f32 sequential oracle within bf16 tolerance."""
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = (jax.random.normal(ks[0], (2, 256, 16)) * 0.5).astype(jnp.bfloat16)
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 256))) * 0.3 + 0.68
    b = (jax.random.normal(ks[2], (2, 256, 8)) * 0.3).astype(jnp.bfloat16)
    c = (jax.random.normal(ks[3], (2, 256, 8)) * 0.3).astype(jnp.bfloat16)
    out = ssm_scan(x, a, b, c, interpret=True)
    exp = ref.ssm_scan_ref(x.astype(jnp.float32), a,
                           b.astype(jnp.float32), c.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-2, atol=5e-2)


def test_ssm_scan_chunk_invariance():
    """Chunk size must not change the result (associativity of the scan)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (2, 256, 16)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 256))) * 0.3 + 0.68
    b = jax.random.normal(ks[2], (2, 256, 8)) * 0.3
    c = jax.random.normal(ks[3], (2, 256, 8)) * 0.3
    o1 = ssm_scan(x, a, b, c, chunk=64, interpret=True)
    o2 = ssm_scan(x, a, b, c, chunk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bh,l,p,n,chunk", [
    (2, 128, 16, 8, 128), (4, 256, 32, 16, 128), (1, 512, 64, 64, 128),
    (2, 256, 16, 8, 64), (3, 96, 8, 4, 128),       # non-tileable -> sequential
])
def test_ssm_chunked_ref_matches_sequential(bh, l, p, n, chunk):
    """The chunked SSD reference (the XLA fallback + dry-run path) must equal
    the sequential oracle for any chunking."""
    ks = jax.random.split(jax.random.PRNGKey(bh * l + p), 4)
    x = jax.random.normal(ks[0], (bh, l, p)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (bh, l))) * 0.3 + 0.68
    b = jax.random.normal(ks[2], (bh, l, n)) * 0.3
    c = jax.random.normal(ks[3], (bh, l, n)) * 0.3
    out = ref.ssm_scan_chunked_ref(x, a, b, c, chunk=chunk)
    exp = ref.ssm_scan_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       l=st.sampled_from([128, 256, 384]),
       decay_lo=st.floats(min_value=0.05, max_value=0.95))
def test_ssm_chunked_ref_property(seed, l, decay_lo):
    """Property sweep: chunked == sequential across decay ranges (incl. strong
    decay, where the log-space segsum must not under/overflow)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (2, l, 8))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, l))) * (0.999 - decay_lo) + decay_lo
    b = jax.random.normal(ks[2], (2, l, 4)) * 0.3
    c = jax.random.normal(ks[3], (2, l, 4)) * 0.3
    out = ref.ssm_scan_chunked_ref(x, a, b, c)
    exp = ref.ssm_scan_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)


def test_ssm_chunked_ref_grads_match_sequential():
    """ops.ssm backward runs the chunked VJP — it must match the sequential
    oracle's gradients."""
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    x = jax.random.normal(ks[0], (2, 128, 8)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 128))) * 0.3 + 0.68
    b = jax.random.normal(ks[2], (2, 128, 4)) * 0.3
    c = jax.random.normal(ks[3], (2, 128, 4)) * 0.3
    g1 = jax.grad(lambda *t: jnp.sum(ref.ssm_scan_chunked_ref(*t)), (0, 1, 2, 3))(x, a, b, c)
    g2 = jax.grad(lambda *t: jnp.sum(ref.ssm_scan_ref(*t)), (0, 1, 2, 3))(x, a, b, c)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_ssm_decay_contraction(seed):
    """|a| < 1 everywhere => output magnitude is bounded by
    sum of geometric series of input magnitudes (stability property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (1, 128, 8))
    a = jnp.full((1, 128), 0.9)
    b = jax.random.normal(ks[2], (1, 128, 4)) * 0.1
    c = jax.random.normal(ks[3], (1, 128, 4)) * 0.1
    out = ref.ssm_scan_ref(x, a, b, c)
    bound = (jnp.max(jnp.abs(x)) * jnp.max(jnp.abs(b)) * jnp.max(jnp.abs(c))
             * 4 / (1 - 0.9))
    assert float(jnp.max(jnp.abs(out))) <= float(bound) + 1e-3

"""Device-resident materialization + prefetch pipeline invariants.

The streaming engine's contract (core/sweep.py docstring): the jitted
mixed-radix decode reproduces the host chunk builder bit-for-bit, both
materialization modes feed one program instance, and the prefetch pipeline
folds in chunk order — so every (materialize, prefetch) combination produces
bit-identical reducer states, monolithic results, Pareto fronts, and
co-design fronts, including the repeat-last-row padded final chunk.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.env import prefetch_depth
from repro.core.power import Traffic, engine_x64
from repro.core.sweep import (
    ChunkReducer,
    MinReducer,
    _as_f64,
    _decode_program,
    grid_spec,
    sweep,
    sweep_chunked,
)
from repro.core.search import codesign_pareto, pareto_search
from repro.core.faults import HEALTHY, FaultModel, faulted_columns_fn
from repro.core.accelerator import ChipletSpec
from repro.core.workloads import CNN_WORKLOADS

T = Traffic(bytes_read=2e9, bytes_written=1e9, n_transfers=128)
# 5 topologies x 3 x 2 x 2 = 60 rows; chunk_size=7 leaves a 4-row padded tail
AXES = dict(n_gateways=(16.0, 32.0, 64.0), n_lambda=(4.0, 8.0),
            mem_bw_bytes_per_s=(50e9, 100e9))
CHUNK = 7

MODEL = FaultModel(p_lambda=0.05, p_bank=0.1, p_gateway=0.02, wpe_loss=0.1,
                   drift_sigma_db=0.3, tuning_sigma=0.1)


class _Collect(ChunkReducer):
    """Concatenates every chunk's metrics — the reducer-state fingerprint."""

    def init(self, spec):
        return []

    def step(self, carry, chunk):
        carry.append({k: np.array(v) for k, v in chunk.metrics.items()})
        return carry

    def finish(self, carry, spec):
        return {k: np.concatenate([c[k] for c in carry], axis=-1)
                for k in carry[0]}


def _assert_same(a, b, ctx):
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{ctx}: {k}")


# ---------------------------------------------------------------------------
# decode program vs host chunk builder
# ---------------------------------------------------------------------------


def test_device_decode_matches_chunk_cols_exactly():
    spec = grid_spec(("tree", "trine", "elec"), **AXES)
    decode = _decode_program(spec, CHUNK)
    with engine_x64():
        tables = {k: _as_f64(v) for k, v in spec.axes.items()}
        base = {k: _as_f64(v) for k, v in spec.base.items()}
        for start in range(0, spec.n, CHUNK):
            stop = min(start + CHUNK, spec.n)
            cols_d, topo_d = decode(tables, base, np.int64(start))
            cols_h, topo_h = spec.chunk_cols(start, stop)
            valid = stop - start
            np.testing.assert_array_equal(
                np.asarray(topo_d)[:valid], topo_h, err_msg=f"@{start}")
            for k, v in cols_h.items():
                np.testing.assert_array_equal(
                    np.asarray(cols_d[k])[:valid], v, err_msg=f"{k}@{start}")
            # padding clamps to the final row (repeat-last-row)
            if valid < CHUNK:
                for k in cols_h:
                    assert np.all(np.asarray(cols_d[k])[valid:]
                                  == cols_h[k][-1])


# ---------------------------------------------------------------------------
# network sweeps: modes x depths, padded tail included
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("materialize", ["device", "host"])
@pytest.mark.parametrize("depth", [0, 1, 2])
def test_network_sweep_bitwise_across_modes_and_depths(materialize, depth):
    mono = sweep(T, **AXES)
    out = sweep_chunked(T, _Collect(), chunk_size=CHUNK,
                        materialize=materialize, prefetch=depth, **AXES)
    _assert_same(out, mono.metrics, f"{materialize}/depth={depth}")

    best = sweep_chunked(T, MinReducer("energy_j"), chunk_size=CHUNK,
                         materialize=materialize, prefetch=depth, **AXES)
    i, _ = mono.best("energy_j")
    assert best["index"] == i
    assert best["value"] == mono.metrics["energy_j"][i]


def test_multi_workload_traffic_bitwise_across_depths():
    traffics = [T, Traffic(bytes_read=5e8, bytes_written=5e8, n_transfers=32)]
    ref = sweep_chunked(traffics, _Collect(), chunk_size=CHUNK, prefetch=0,
                        **AXES)
    assert ref["latency_s"].shape[0] == 2  # leading workload axis
    for depth in (1, 2):
        for mat in ("device", "host"):
            out = sweep_chunked(traffics, _Collect(), chunk_size=CHUNK,
                                materialize=mat, prefetch=depth, **AXES)
            _assert_same(out, ref, f"{mat}/depth={depth}")


def test_pareto_search_front_identical_across_modes_and_depths():
    ref = pareto_search(T, chunk_size=CHUNK, materialize="host", prefetch=0,
                        **AXES)
    for depth in (0, 2):
        for mat in ("device", "host"):
            fr = pareto_search(T, chunk_size=CHUNK, materialize=mat,
                               prefetch=depth, **AXES)
            a, b = fr.canonical(), ref.canonical()
            np.testing.assert_array_equal(a.points, b.points)
            np.testing.assert_array_equal(a.indices, b.indices)


# ---------------------------------------------------------------------------
# faulted sweeps (scenario composes on-device)
# ---------------------------------------------------------------------------


def test_faulted_healthy_is_bitwise_plain_every_mode():
    plain = sweep(T, **AXES)
    for depth in (0, 2):
        for mat in ("device", "host"):
            out = sweep_chunked(T, _Collect(), chunk_size=CHUNK,
                                columns_fn=faulted_columns_fn(HEALTHY),
                                materialize=mat, prefetch=depth, **AXES)
            _assert_same(out, plain.metrics, f"{mat}/depth={depth}")


def test_faulted_batched_scenarios_bitwise_across_modes_and_depths():
    scen = MODEL.sample(6, rng=7)
    ref = None
    for depth in (0, 1, 2):
        for mat in ("device", "host"):
            out = sweep_chunked(T, _Collect(), chunk_size=CHUNK,
                                columns_fn=faulted_columns_fn(scen),
                                materialize=mat, prefetch=depth, **AXES)
            assert out["latency_s"].shape[0] == 6  # scenario axis survives
            if ref is None:
                ref = out
            else:
                _assert_same(out, ref, f"{mat}/depth={depth}")


def test_legacy_columns_fn_still_runs_on_host_columns():
    """An arbitrary callable (no .scenario) gets host-materialized columns
    and its own pipeline, matching the numpy reference path at f64 rtol."""
    scen = MODEL.expected()
    hook = faulted_columns_fn(scen)
    ref = sweep_chunked(T, _Collect(), chunk_size=CHUNK,
                        columns_fn=hook, prefetch=0, **AXES)
    seen = []

    def legacy(cols, topo_id, topologies):
        seen.append(int(topo_id.size))
        return hook(cols, topo_id, topologies)

    out = sweep_chunked(T, _Collect(), chunk_size=CHUNK, columns_fn=legacy,
                        prefetch=2, **AXES)
    assert seen and all(s == CHUNK for s in seen)  # host columns, padded
    for k in ref:
        np.testing.assert_allclose(out[k], ref[k], rtol=1e-7)


# ---------------------------------------------------------------------------
# co-design fronts
# ---------------------------------------------------------------------------


def test_codesign_front_identical_across_modes_and_depths():
    wl = CNN_WORKLOADS["LeNet5"]()
    mixes = [[ChipletSpec(512, 32)], [ChipletSpec(256, 9), ChipletSpec(128, 49)]]
    kw = dict(topologies=("tree", "trine", "elec"), chunk_size=5,
              n_gateways=(16.0, 32.0), n_lambda=(4.0, 8.0))
    ref_front, ref_spec = codesign_pareto(wl, mixes, materialize="host",
                                          prefetch=0, **kw)
    ref = ref_front.canonical()
    for depth in (0, 2):
        for mat in ("device", "host"):
            front, spec = codesign_pareto(wl, mixes, materialize=mat,
                                          prefetch=depth, **kw)
            assert spec.n == ref_spec.n
            got = front.canonical()
            np.testing.assert_array_equal(got.points, ref.points,
                                          err_msg=f"{mat}/depth={depth}")
            np.testing.assert_array_equal(got.indices, ref.indices,
                                          err_msg=f"{mat}/depth={depth}")


# ---------------------------------------------------------------------------
# knobs and validation
# ---------------------------------------------------------------------------


def test_prefetch_depth_env_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_PREFETCH", raising=False)
    assert prefetch_depth() == 2
    monkeypatch.setenv("REPRO_PREFETCH", "0")
    assert prefetch_depth() == 0
    monkeypatch.setenv("REPRO_PREFETCH", "5")
    assert prefetch_depth() == 5
    monkeypatch.setenv("REPRO_PREFETCH", "-3")
    assert prefetch_depth() == 0  # clamped
    monkeypatch.setenv("REPRO_PREFETCH", "banana")
    assert prefetch_depth() == 2  # unparseable -> default


def test_repro_prefetch_env_changes_schedule_not_results(monkeypatch):
    ref = sweep_chunked(T, _Collect(), chunk_size=CHUNK, prefetch=0, **AXES)
    monkeypatch.setenv("REPRO_PREFETCH", "3")
    out = sweep_chunked(T, _Collect(), chunk_size=CHUNK, **AXES)
    _assert_same(out, ref, "env-depth")


def test_bad_materialize_rejected():
    with pytest.raises(ValueError, match="materialize"):
        sweep_chunked(T, _Collect(), materialize="gpu", **AXES)


def test_spacx_subcluster_gateways_rejected_eagerly():
    with pytest.raises(ValueError):
        sweep_chunked(T, _Collect(), topologies=("spacx",),
                      n_gateways=(4.0,), n_lambda=(8.0,))
    wl = CNN_WORKLOADS["LeNet5"]()
    with pytest.raises(ValueError):
        codesign_pareto(wl, [[ChipletSpec(256, 9)]], topologies=("spacx",),
                        n_gateways=(4.0,), n_lambda=(8.0,))


def test_engine_runs_float64_even_in_f32_session():
    """The engine promises fixed f64 execution regardless of the session's
    jax_enable_x64 — the foundation of all the bitwise guarantees above."""
    assert jnp.asarray(1.0).dtype == jnp.float32  # test session is f32
    out = sweep_chunked(T, _Collect(), chunk_size=CHUNK, **AXES)
    assert out["energy_j"].dtype == np.float64
    mono = sweep(T, **AXES)
    assert mono.metrics["energy_j"].dtype == np.float64

"""Unit tests for the HLO roofline analyzer on synthetic HLO text:
trip-count multipliers, ring-factor byte accounting, and the wire-dtype
correction rules (movement vs reduction collectives, fusion interiors)."""

import pytest

from repro.launch import hlo_analysis as H


def _analyze(hlo, n=8):
    return H.analyze_hlo(hlo, n)


def test_trip_count_multiplier_scales_dot_flops():
    hlo = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  ROOT %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
}
"""
    stats = _analyze(hlo)
    # one 8x8x8 dot per trip, 12 trips
    assert stats.dot_flops == pytest.approx(12 * 2 * 8 * 8 * 8)
    assert stats.max_trip == 12


def test_allreduce_ring_factor_and_no_correction_for_f32():
    hlo = """
HloModule m

ENTRY %main (g: f32[1024]) -> f32[1024] {
  %g = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%g), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    stats = _analyze(hlo, n=8)
    expected = 2 * (8 - 1) / 8 * 1024 * 4
    assert stats.collective_bytes == pytest.approx(expected)
    assert stats.collective_bytes_raw == pytest.approx(expected)


def test_movement_collective_consumer_narrowing():
    """all-gather(f32) whose only consumer converts to bf16 counts at bf16
    (TPU CollectiveQuantizer sinks the convert into the gather)."""
    hlo = """
HloModule m

ENTRY %main (w: f32[128,64]) -> bf16[1024,64] {
  %w = f32[128,64]{1,0} parameter(0)
  %ag = f32[1024,64]{1,0} all-gather(%w), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %c = bf16[1024,64]{1,0} convert(%ag)
}
"""
    stats = _analyze(hlo, n=8)
    raw = (8 - 1) / 8 * 1024 * 64 * 4
    assert stats.collective_bytes_raw == pytest.approx(raw)
    assert stats.collective_bytes == pytest.approx(raw / 2)


def test_reduction_needs_both_sides_narrow():
    """all-reduce narrowed ONLY under the normalization sandwich
    (bf16 producer AND bf16 consumer); f32-produced grads stay f32."""
    sandwich = """
HloModule m

ENTRY %main (x: bf16[256]) -> bf16[256] {
  %x = bf16[256]{0} parameter(0)
  %up = f32[256]{0} convert(%x)
  %ar = f32[256]{0} all-reduce(%up), replica_groups=[1,8]<=[8], to_apply=%add
  ROOT %dn = bf16[256]{0} convert(%ar)
}
"""
    stats = _analyze(sandwich, n=8)
    raw = 2 * (8 - 1) / 8 * 256 * 4
    assert stats.collective_bytes_raw == pytest.approx(raw)
    assert stats.collective_bytes == pytest.approx(raw / 2)

    one_sided = """
HloModule m

ENTRY %main (x: f32[256]) -> bf16[256] {
  %x = f32[256]{0} parameter(0)
  %ar = f32[256]{0} all-reduce(%x), replica_groups=[1,8]<=[8], to_apply=%add
  ROOT %dn = bf16[256]{0} convert(%ar)
}
"""
    stats = _analyze(one_sided, n=8)
    assert stats.collective_bytes == pytest.approx(raw)   # NOT narrowed


def test_int8_producer_detected_through_fusion():
    """all-gather over a value produced by an int8-slicing fusion counts at
    1 byte (the scan-carried wire pairs)."""
    hlo = """
HloModule m

%slicer (p0: s8[32,16,64], p1: s32[]) -> s8[16,64] {
  %p0 = s8[32,16,64]{2,1,0} parameter(0)
  %p1 = s32[] parameter(1)
  %ds = s8[1,16,64]{2,1,0} dynamic-slice(%p0, %p1), dynamic_slice_sizes={1,16,64}
  ROOT %r = s8[16,64]{2,1,0} reshape(%ds)
}

ENTRY %main (q: s8[32,16,64], i: s32[]) -> s8[128,64] {
  %q = s8[32,16,64]{2,1,0} parameter(0)
  %i = s32[] parameter(1)
  %sl = s8[16,64]{2,1,0} fusion(%q, %i), kind=kLoop, calls=%slicer
  ROOT %ag = s8[128,64]{1,0} all-gather(%sl), replica_groups=[1,8]<=[8], dimensions={0}
}
"""
    stats = _analyze(hlo, n=8)
    expected = (8 - 1) / 8 * 128 * 64 * 1
    assert stats.collective_bytes == pytest.approx(expected)


def test_fusion_interior_convert_detected():
    """CPU FloatNormalization hides f32<->bf16 pairs inside fusions; the
    interior convert sets the payload dtype."""
    hlo = """
HloModule m

%sandwich (p0: f32[512,64]) -> f32[512,64] {
  %p0 = f32[512,64]{1,0} parameter(0)
  %dn = bf16[512,64]{1,0} convert(%p0)
  ROOT %up = f32[512,64]{1,0} convert(%dn)
}

ENTRY %main (w: f32[64,64]) -> f32[512,64] {
  %w = f32[64,64]{1,0} parameter(0)
  %ag = f32[512,64]{1,0} all-gather(%w), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %f = f32[512,64]{1,0} fusion(%ag), kind=kLoop, calls=%sandwich
}
"""
    stats = _analyze(hlo, n=8)
    raw = (8 - 1) / 8 * 512 * 64 * 4
    assert stats.collective_bytes_raw == pytest.approx(raw)
    assert stats.collective_bytes == pytest.approx(raw / 2)


def test_dot_result_bytes_consumer_narrowed():
    hlo = """
HloModule m

ENTRY %main (x: bf16[128,128], w: bf16[128,128]) -> bf16[128,128] {
  %x = bf16[128,128]{1,0} parameter(0)
  %w = bf16[128,128]{1,0} parameter(1)
  %d = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %c = bf16[128,128]{1,0} convert(%d)
}
"""
    stats = _analyze(hlo, n=8)
    # operands bf16 (2 x 128*128*2) + result narrowed to bf16
    assert stats.dot_bytes == pytest.approx(3 * 128 * 128 * 2)
    assert stats.dot_flops == pytest.approx(2 * 128 ** 3)

"""Shared pytest config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the 1 real CPU device; multi-device tests use subprocesses.

If the real `hypothesis` package is missing (the container image does not
bake it in), register the deterministic stub in tests/_hypothesis_stub.py
under the same module name before any test module imports it.
"""
import importlib.util
import sys
from pathlib import Path

import pytest

try:  # pragma: no cover - depends on the environment image
    import hypothesis  # noqa: F401
except ImportError:
    _stub_path = Path(__file__).resolve().parent / "_hypothesis_stub.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")

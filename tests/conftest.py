"""Shared pytest config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the 1 real CPU device; multi-device tests use subprocesses."""
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: multi-device subprocess tests")

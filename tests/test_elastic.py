"""Elastic scaling + restart robustness: a checkpoint written under one mesh
restores onto a DIFFERENT mesh (fewer/more devices, different axis split) and
training continues — the lose-a-pod -> re-mesh -> restore -> continue path
(DESIGN.md §7), run with 8 fake CPU devices in a subprocess — plus in-process
supervisor-loop tests: multi-failure restart schedules and fallback past a
corrupted latest checkpoint."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint import store
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig, run_with_restarts

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp

    from repro import configs as C
    from repro.checkpoint import store
    from repro.models import model as M
    from repro.optim import adamw
    from repro.parallel import sharding as S, actx
    from repro.runtime.trainer import make_train_step
    from repro.launch.mesh import make_test_mesh

    cfg = C.get_reduced("yi_6b")
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    params, pspecs = M.init(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(opt, params)
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32)}
    ckdir = tempfile.mkdtemp()

    def build(mesh):
        rules = S.rules_for(cfg, mesh)
        st_sh = S.enforce_divisibility(
            S.tree_shardings(mesh, adamw.state_specs(pspecs), rules),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        b_sh = S.train_batch_shardings(cfg, mesh, batch)
        step = jax.jit(make_train_step(cfg, opt), in_shardings=(st_sh, b_sh))
        return step, st_sh, b_sh

    # ---- phase 1: 8 devices as (pod=2, data=2, model=2) ----
    mesh1 = make_test_mesh(data=2, model=2, pod=2)
    step1, st_sh1, b_sh1 = build(mesh1)
    dp1 = S.batch_axes(mesh1, 4)
    with mesh1, actx.activation_sharding(mesh1, dp1):
        s = jax.device_put(state, st_sh1)
        b = jax.device_put(batch, b_sh1)
        for _ in range(2):
            s, m = step1(s, b)
    store.save(ckdir, 2, s)
    loss1 = float(m["loss"])

    # ---- phase 2: "lost a pod" -> re-mesh 8 devices as (data=4, model=2) ----
    mesh2 = make_test_mesh(data=4, model=2)
    step2, st_sh2, b_sh2 = build(mesh2)
    restored = store.restore(ckdir, 2, s, shardings=st_sh2)
    # bitwise identical params after the re-shard
    for a, c in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    dp2 = S.batch_axes(mesh2, 4)
    with mesh2, actx.activation_sharding(mesh2, dp2):
        b2 = jax.device_put(batch, b_sh2)
        s2, m2 = step2(restored, b2)
        s2, m2 = step2(s2, jax.device_put(batch, b_sh2))
    assert np.isfinite(float(m2["loss"]))

    # ---- determinism check: same continuation on the original mesh ----
    with mesh1, actx.activation_sharding(mesh1, dp1):
        r1 = store.restore(ckdir, 2, s, shardings=st_sh1)
        c1, n1 = step1(r1, jax.device_put(batch, b_sh1))
        c1, n1 = step1(c1, jax.device_put(batch, b_sh1))
    np.testing.assert_allclose(float(n1["loss"]), float(m2["loss"]),
                               rtol=1e-5, atol=1e-6)
    print("OK elastic re-mesh restore + continue")
""")


@pytest.mark.slow
def test_elastic_remesh_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "OK elastic re-mesh restore + continue" in r.stdout


# ---------------------------------------------------------------------------
# supervisor-loop restart robustness (in-process, single device)
# ---------------------------------------------------------------------------

CFG = C.get_reduced("yi_6b")
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=16)
DATA = DataConfig(global_batch=2, seq_len=64)


def _trainer(tmp, resume=True):
    return Trainer(CFG, OPT, DATA,
                   TrainerConfig(ckpt_dir=str(tmp), ckpt_every=2,
                                 log_every=1000),
                   resume=resume)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


def test_run_with_restarts_multi_failure(tmp_path):
    """Two injected failures in one supervised run: the final state is
    bitwise identical to an uninterrupted run and the merged history covers
    every step exactly once, in order."""
    straight = _trainer(tmp_path / "a", resume=False)
    straight.run(8, quiet=True)

    tr = run_with_restarts(lambda: _trainer(tmp_path / "b"), total_steps=8,
                           fail_at=(3, 5))
    for a, b in zip(_leaves(straight.state), _leaves(tr.state)):
        np.testing.assert_array_equal(a, b)
    assert [h["step"] for h in tr.history] == list(range(1, 9))
    np.testing.assert_allclose(
        [h["loss"] for h in tr.history],
        [h["loss"] for h in straight.history], rtol=1e-6)


@pytest.mark.parametrize("corruption", ["leaf_bytes", "leaf_truncated",
                                        "manifest_missing"])
def test_corrupt_latest_checkpoint_falls_back(tmp_path, corruption):
    """Resume survives a corrupt/truncated latest checkpoint: the trainer
    falls back to the previous retained step and deletes the bad
    directory so retention stops tripping on it."""
    tr = _trainer(tmp_path, resume=False)
    tr.run(8, quiet=True)
    assert store.retained_steps(tmp_path) == [4, 6, 8]

    latest = tmp_path / "step_00000008"
    if corruption == "manifest_missing":
        (latest / "manifest.json").unlink()
    else:
        victim = sorted(latest.glob("leaf_*.npy"))[0]
        raw = victim.read_bytes()
        victim.write_bytes(b"corrupted!" + raw[10:]
                           if corruption == "leaf_bytes" else raw[:32])

    resumed = _trainer(tmp_path, resume=True)
    assert resumed.start_step == 6
    assert not latest.exists()
    assert store.retained_steps(tmp_path) == [4, 6]
    for a, b in zip(_leaves(store.restore(tmp_path, 6, resumed.state)),
                    _leaves(resumed.state)):
        np.testing.assert_array_equal(a, b)


def test_all_checkpoints_corrupt_starts_fresh(tmp_path):
    """When every retained checkpoint fails verification the trainer starts
    from step 0 instead of crashing."""
    tr = _trainer(tmp_path, resume=False)
    tr.run(4, quiet=True)
    for d in tmp_path.glob("step_*"):
        (d / "manifest.json").unlink()
    resumed = _trainer(tmp_path, resume=True)
    assert resumed.start_step == 0
    assert store.retained_steps(tmp_path) == []

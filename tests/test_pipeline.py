"""GPipe pipeline-parallel schedule: correctness vs the sequential oracle,
differentiability through the staircase, and the bubble/cost planner.

The shard_map schedule needs >1 device on the pipe axis — runs in a
subprocess with 8 fake CPU devices (same pattern as test_distributed)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.parallel.pipeline import choose_microbatches, pipeline_cost

REPO = Path(__file__).resolve().parents[1]


def test_bubble_fraction_math():
    c = pipeline_cost(n_stages=4, n_micro=12, step_flops=1e12,
                      hop_bytes=1e6, peak_flops=1e14, link_bw=5e10)
    assert c["ticks"] == 15
    assert c["bubble_frac"] == pytest.approx(3 / 15)
    # compute-dominated tick here
    assert c["tick_s"] == pytest.approx((1e12 / 12) / 1e14)


from hypothesis import given, settings, strategies as st


@settings(max_examples=40, deadline=None)
@given(s=st.integers(min_value=1, max_value=32),
       m=st.integers(min_value=1, max_value=128))
def test_bubble_monotonic_in_microbatches(s, m):
    """More microbatches never increase the bubble fraction; the bubble
    vanishes as M→∞ and equals (S-1)/S at M=1 — the TRINE stage-count
    argument in pipeline form."""
    c1 = pipeline_cost(s, m, 1e12, 1e6, 1e14, 5e10)
    c2 = pipeline_cost(s, m + 1, 1e12, 1e6, 1e14, 5e10)
    assert c2["bubble_frac"] <= c1["bubble_frac"] + 1e-12
    assert pipeline_cost(s, 1, 1, 1, 1, 1)["bubble_frac"] == \
        (s - 1) / s


@settings(max_examples=40, deadline=None)
@given(by=st.floats(min_value=1e3, max_value=1e12),
       win=st.floats(min_value=1e-6, max_value=10.0))
def test_collective_channels_cover_bytes(by, win):
    """The planner provisions enough parallel channels that the collective
    fits its overlap window at link bandwidth — and no more than needed
    (bandwidth matching, paper §IV) unless chunk-size clamped."""
    from repro.core.planner import plan_collective_channels
    bw = 5e10
    ch = plan_collective_channels(by, win, bw)
    assert ch >= 1
    need = by / (win * bw)
    if need <= 8 and by / max(need, 1) >= (1 << 20):   # unclamped region
        assert ch >= min(8, int(need))                 # covers the demand
        assert ch <= max(1, int(need) + 1)             # no over-provision


def test_choose_microbatches_hits_target():
    for s in (2, 4, 8):
        m = choose_microbatches(s, target_bubble=0.1)
        assert (s - 1) / (m + s - 1) <= 0.1 or m == 64
    assert choose_microbatches(1) == 1  # no pipeline, no bubble


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.parallel import pipeline as PP

    mesh = jax.make_mesh((4, 2), ("pipe", "data"))

    # stage = 2-layer MLP stack; stage params leaves (S, L, ...)
    S, L, D, M, MB = 4, 2, 16, 6, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    params = {
        "w": jax.random.normal(ks[0], (S, L, D, D)) * (D ** -0.5),
        "b": jax.random.normal(ks[1], (S, L, D)) * 0.01,
    }

    def stage_fn(p, x):       # p leaves (L, ...)
        def layer(h, wl):
            w, b = wl
            return jnp.tanh(h @ w + b), None
        h, _ = jax.lax.scan(layer, x, (p["w"], p["b"]))
        return h

    x = jax.random.normal(jax.random.PRNGKey(2), (M, MB, D))

    out = PP.pipelined_apply(stage_fn, params, x, mesh, axis="pipe")
    ref = PP.sequential_reference(stage_fn, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("OK pipeline forward")

    # differentiable through the schedule (backward staircase via ppermute
    # transpose); grads match the sequential oracle's
    def loss_pp(p):
        return jnp.sum(PP.pipelined_apply(stage_fn, p, x, mesh, axis="pipe") ** 2)
    def loss_ref(p):
        return jnp.sum(PP.sequential_reference(stage_fn, p, x) ** 2)
    g_pp = jax.grad(loss_pp)(params)
    g_ref = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree.leaves(g_pp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-5)
    print("OK pipeline backward")

    # stage splitting round-trip
    stacked = {"w": params["w"].reshape(S * L, D, D)}
    split = PP.split_stages(stacked, S)
    assert split["w"].shape == (S, L, D, D)
    np.testing.assert_array_equal(np.asarray(split["w"]), np.asarray(params["w"]))
    print("OK split_stages")
""")


@pytest.mark.slow
def test_pipeline_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    for marker in ("OK pipeline forward", "OK pipeline backward",
                   "OK split_stages"):
        assert marker in r.stdout
